//! 4×u64 little-endian limb arithmetic helpers.
//!
//! Everything here is a `const fn` so that the Montgomery constants of each
//! field (R, R², R³, −N⁻¹ mod 2⁶⁴) can be *derived* from the modulus at
//! compile time instead of being pasted in as magic numbers.

/// Add with carry: returns (sum, carry).
#[inline(always)]
pub const fn adc(a: u64, b: u64, carry: u64) -> (u64, u64) {
    let t = (a as u128) + (b as u128) + (carry as u128);
    (t as u64, (t >> 64) as u64)
}

/// Subtract with borrow: returns (diff, borrow) where borrow ∈ {0,1}.
#[inline(always)]
pub const fn sbb(a: u64, b: u64, borrow: u64) -> (u64, u64) {
    let t = (a as u128).wrapping_sub((b as u128) + (borrow as u128));
    (t as u64, ((t >> 64) as u64) & 1)
}

/// Multiply-accumulate: a + b*c + carry, returns (lo, hi).
#[inline(always)]
pub const fn mac(a: u64, b: u64, c: u64, carry: u64) -> (u64, u64) {
    let t = (a as u128) + (b as u128) * (c as u128) + (carry as u128);
    (t as u64, (t >> 64) as u64)
}

/// a < b over 4 limbs.
#[inline(always)]
pub const fn lt(a: &[u64; 4], b: &[u64; 4]) -> bool {
    let mut i = 3;
    loop {
        if a[i] < b[i] {
            return true;
        }
        if a[i] > b[i] {
            return false;
        }
        if i == 0 {
            return false;
        }
        i -= 1;
    }
}

/// a == 0 over 4 limbs.
#[inline(always)]
pub const fn is_zero(a: &[u64; 4]) -> bool {
    a[0] == 0 && a[1] == 0 && a[2] == 0 && a[3] == 0
}

/// a + b (no reduction); returns (limbs, carry).
#[inline(always)]
pub const fn add4(a: &[u64; 4], b: &[u64; 4]) -> ([u64; 4], u64) {
    let (r0, c) = adc(a[0], b[0], 0);
    let (r1, c) = adc(a[1], b[1], c);
    let (r2, c) = adc(a[2], b[2], c);
    let (r3, c) = adc(a[3], b[3], c);
    ([r0, r1, r2, r3], c)
}

/// a - b (no reduction); returns (limbs, borrow).
#[inline(always)]
pub const fn sub4(a: &[u64; 4], b: &[u64; 4]) -> ([u64; 4], u64) {
    let (r0, bw) = sbb(a[0], b[0], 0);
    let (r1, bw) = sbb(a[1], b[1], bw);
    let (r2, bw) = sbb(a[2], b[2], bw);
    let (r3, bw) = sbb(a[3], b[3], bw);
    ([r0, r1, r2, r3], bw)
}

/// (a + b) mod n, assuming a, b < n.
#[inline(always)]
pub const fn add_mod(a: &[u64; 4], b: &[u64; 4], n: &[u64; 4]) -> [u64; 4] {
    let (s, carry) = add4(a, b);
    // subtract n if overflowed or >= n
    if carry == 1 || !lt(&s, n) {
        let (r, _) = sub4(&s, n);
        r
    } else {
        s
    }
}

/// (a - b) mod n, assuming a, b < n.
#[inline(always)]
pub const fn sub_mod(a: &[u64; 4], b: &[u64; 4], n: &[u64; 4]) -> [u64; 4] {
    let (d, borrow) = sub4(a, b);
    if borrow == 1 {
        let (r, _) = add4(&d, n);
        r
    } else {
        d
    }
}

/// −a mod n, assuming a < n.
#[inline(always)]
pub const fn neg_mod(a: &[u64; 4], n: &[u64; 4]) -> [u64; 4] {
    if is_zero(a) {
        [0; 4]
    } else {
        let (r, _) = sub4(n, a);
        r
    }
}

/// 2a mod n, assuming a < n (n < 2^255 so the shifted-out bit matters).
#[inline(always)]
pub const fn double_mod(a: &[u64; 4], n: &[u64; 4]) -> [u64; 4] {
    let carry = a[3] >> 63;
    let s = [
        a[0] << 1,
        (a[1] << 1) | (a[0] >> 63),
        (a[2] << 1) | (a[1] >> 63),
        (a[3] << 1) | (a[2] >> 63),
    ];
    if carry == 1 || !lt(&s, n) {
        let (r, _) = sub4(&s, n);
        r
    } else {
        s
    }
}

/// Montgomery multiplication (CIOS): a·b·R⁻¹ mod n where R = 2²⁵⁶.
/// Requires n odd, n < 2²⁵⁵, `ninv` = −n⁻¹ mod 2⁶⁴, a, b < n.
pub const fn mont_mul(a: &[u64; 4], b: &[u64; 4], n: &[u64; 4], ninv: u64) -> [u64; 4] {
    let mut t = [0u64; 6]; // t[4] holds the running high limb, t[5] the carry
    let mut i = 0;
    while i < 4 {
        // t += a[i] * b
        let (t0, c) = mac(t[0], a[i], b[0], 0);
        let (t1, c) = mac(t[1], a[i], b[1], c);
        let (t2, c) = mac(t[2], a[i], b[2], c);
        let (t3, c) = mac(t[3], a[i], b[3], c);
        let (t4, c) = adc(t[4], 0, c);
        t = [t0, t1, t2, t3, t4, c];
        // m = t[0] * ninv mod 2^64; t += m * n; t >>= 64
        let m = t[0].wrapping_mul(ninv);
        let (_, c) = mac(t[0], m, n[0], 0);
        let (r1, c) = mac(t[1], m, n[1], c);
        let (r2, c) = mac(t[2], m, n[2], c);
        let (r3, c) = mac(t[3], m, n[3], c);
        let (r4, c) = adc(t[4], 0, c);
        let r5 = t[5] + c;
        t = [r1, r2, r3, r4, r5, 0];
        i += 1;
    }
    let r = [t[0], t[1], t[2], t[3]];
    // t[4] can be at most 1; final conditional subtraction
    if t[4] == 1 || !lt(&r, n) {
        let (s, _) = sub4(&r, n);
        s
    } else {
        r
    }
}

/// −n⁻¹ mod 2⁶⁴ by Newton's iteration (n odd).
pub const fn mont_ninv(n0: u64) -> u64 {
    // x := n0^{-1} mod 2^64 via x_{k+1} = x_k (2 - n0 x_k); 6 iterations
    let mut x = 1u64;
    let mut i = 0;
    while i < 6 {
        x = x.wrapping_mul(2u64.wrapping_sub(n0.wrapping_mul(x)));
        i += 1;
    }
    x.wrapping_neg()
}

/// R mod n, with R = 2²⁵⁶, computed by doubling 1 mod n 256 times.
pub const fn mont_r(n: &[u64; 4]) -> [u64; 4] {
    let mut x = [1u64, 0, 0, 0];
    let mut i = 0;
    while i < 256 {
        x = double_mod(&x, n);
        i += 1;
    }
    x
}

/// R² mod n (Montgomery form of R).
pub const fn mont_r2(n: &[u64; 4]) -> [u64; 4] {
    let mut x = mont_r(n);
    let mut i = 0;
    while i < 256 {
        x = double_mod(&x, n);
        i += 1;
    }
    x
}

/// R³ mod n (used for wide 512-bit reduction).
pub const fn mont_r3(n: &[u64; 4], ninv: u64) -> [u64; 4] {
    let r2 = mont_r2(n);
    // mont_mul(R², R²) = R⁴·R⁻¹ = R³
    mont_mul(&r2, &r2, n, ninv)
}

/// n - 2 (for Fermat inversion exponent). n > 2.
pub const fn sub2(n: &[u64; 4]) -> [u64; 4] {
    let (r, _) = sub4(n, &[2, 0, 0, 0]);
    r
}

/// (n + 1) / 4 (sqrt exponent when n ≡ 3 mod 4).
pub const fn plus1_div4(n: &[u64; 4]) -> [u64; 4] {
    let (s, c) = add4(n, &[1, 0, 0, 0]);
    // shift right by 2, bringing in the carry bit
    [
        (s[0] >> 2) | (s[1] << 62),
        (s[1] >> 2) | (s[2] << 62),
        (s[2] >> 2) | (s[3] << 62),
        (s[3] >> 2) | (c << 62),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn adc_sbb_roundtrip() {
        let (s, c) = adc(u64::MAX, 1, 0);
        assert_eq!((s, c), (0, 1));
        let (d, b) = sbb(0, 1, 0);
        assert_eq!((d, b), (u64::MAX, 1));
    }

    #[test]
    fn mont_ninv_is_inverse() {
        for n0 in [1u64, 3, 0xffff_ffff_ffff_ffffu64, 0x3c208c16d87cfd47] {
            if n0 % 2 == 1 {
                let ninv = mont_ninv(n0);
                assert_eq!(n0.wrapping_mul(ninv.wrapping_neg()), 1, "n0={n0}");
            }
        }
    }

    #[test]
    fn add_sub_mod_small() {
        let n = [17u64, 0, 0, 0];
        let a = [12u64, 0, 0, 0];
        let b = [9u64, 0, 0, 0];
        assert_eq!(add_mod(&a, &b, &n), [4, 0, 0, 0]);
        assert_eq!(sub_mod(&b, &a, &n), [14, 0, 0, 0]);
        assert_eq!(neg_mod(&a, &n), [5, 0, 0, 0]);
        assert_eq!(double_mod(&a, &n), [7, 0, 0, 0]);
    }
}
