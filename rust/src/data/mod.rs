//! Synthetic CIFAR-10-shaped dataset.
//!
//! The environment has no network access, so we generate a deterministic
//! dataset with CIFAR-10's shape: 50 000 train / 10 000 test points of
//! dimension 3 072 (zero-padded to 4 096 as in the paper), 10 classes.
//! The proof system's cost depends only on tensor shapes, never on pixel
//! values, so this substitution preserves every measured quantity
//! (DESIGN.md §Documented deviations). Class structure (a random class
//! centroid plus noise) gives the e2e example a learnable signal.

use crate::model::ModelConfig;
use crate::util::rng::Rng;

/// CIFAR-10 native dimension and its padded power of two.
pub const CIFAR_DIM: usize = 3072;
pub const CIFAR_DIM_PADDED: usize = 4096;
pub const CIFAR_CLASSES: usize = 10;
pub const CIFAR_TRAIN: usize = 50_000;

/// A quantized dataset: row-major points at scale 2^R plus integer labels.
#[derive(Clone, Debug)]
pub struct Dataset {
    pub dim: usize,
    pub points: Vec<Vec<i64>>,
    pub labels: Vec<usize>,
    pub num_classes: usize,
}

impl Dataset {
    /// Generate `n` points of dimension `dim` at scale 2^r_bits with `k`
    /// classes. Points are centroid + noise, centroids well-separated.
    pub fn synthetic(n: usize, dim: usize, k: usize, r_bits: u32, seed: u64) -> Self {
        let mut rng = Rng::seed_from_u64(seed);
        let scale = 1i64 << r_bits;
        // centroids with entries in [−scale/2, scale/2]
        let centroids: Vec<Vec<i64>> = (0..k)
            .map(|_| (0..dim).map(|_| rng.gen_i64(-scale / 2, scale / 2 + 1)).collect())
            .collect();
        let noise = scale / 4;
        let mut points = Vec::with_capacity(n);
        let mut labels = Vec::with_capacity(n);
        for i in 0..n {
            let c = i % k;
            let p: Vec<i64> = centroids[c]
                .iter()
                .map(|&v| (v + rng.gen_i64(-noise, noise + 1)).clamp(-scale + 1, scale - 1))
                .collect();
            points.push(p);
            labels.push(c);
        }
        Self {
            dim,
            points,
            labels,
            num_classes: k,
        }
    }

    /// CIFAR-10-shaped synthetic training set (small `n` for examples).
    pub fn cifar10_like(n: usize, r_bits: u32, seed: u64) -> Self {
        Self::synthetic(n, CIFAR_DIM, CIFAR_CLASSES, r_bits, seed)
    }

    pub fn len(&self) -> usize {
        self.points.len()
    }

    pub fn is_empty(&self) -> bool {
        self.points.is_empty()
    }

    /// Assemble batch `idx` (wrapping) as padded X, one-hot Y at scale 2^R
    /// for a model of config `cfg`.
    pub fn batch(&self, cfg: &ModelConfig, idx: usize) -> (Vec<i64>, Vec<i64>) {
        let (b, d) = (cfg.batch, cfg.width);
        assert!(d >= self.dim, "model width must cover data dim");
        let scale = cfg.scale();
        let mut x = vec![0i64; b * d];
        let mut y = vec![0i64; b * d];
        for i in 0..b {
            let j = (idx * b + i) % self.len();
            x[i * d..i * d + self.dim].copy_from_slice(&self.points[j]);
            y[i * d + self.labels[j]] = scale;
        }
        (x, y)
    }

    /// Fraction of batch points classified correctly by arg-max of the last
    /// layer's rescaled output.
    pub fn batch_accuracy(&self, cfg: &ModelConfig, idx: usize, z_prime_last: &[i64]) -> f64 {
        let (b, d) = (cfg.batch, cfg.width);
        let mut correct = 0usize;
        for i in 0..b {
            let j = (idx * b + i) % self.len();
            let row = &z_prime_last[i * d..(i + 1) * d];
            let pred = (0..self.num_classes)
                .max_by_key(|&c| row[c])
                .unwrap_or(0);
            if pred == self.labels[j] {
                correct += 1;
            }
        }
        correct as f64 / b as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_generation() {
        let a = Dataset::synthetic(100, 32, 10, 16, 7);
        let b = Dataset::synthetic(100, 32, 10, 16, 7);
        assert_eq!(a.points, b.points);
        assert_eq!(a.labels, b.labels);
        let c = Dataset::synthetic(100, 32, 10, 16, 8);
        assert_ne!(a.points, c.points);
    }

    #[test]
    fn values_in_scale_range() {
        let ds = Dataset::synthetic(50, 16, 4, 16, 1);
        let scale = 1i64 << 16;
        for p in &ds.points {
            assert_eq!(p.len(), 16);
            assert!(p.iter().all(|&v| v.abs() < scale));
        }
        assert!(ds.labels.iter().all(|&l| l < 4));
    }

    #[test]
    fn batch_layout() {
        let ds = Dataset::synthetic(10, 6, 3, 16, 2);
        let cfg = ModelConfig::new(1, 8, 4);
        let (x, y) = ds.batch(&cfg, 0);
        assert_eq!(x.len(), 4 * 8);
        // padding zeroed
        for i in 0..4 {
            assert_eq!(x[i * 8 + 6], 0);
            assert_eq!(x[i * 8 + 7], 0);
        }
        // one-hot Y rows sum to the scale
        for i in 0..4 {
            let s: i64 = y[i * 8..(i + 1) * 8].iter().sum();
            assert_eq!(s, cfg.scale());
        }
    }

    #[test]
    fn batches_wrap() {
        let ds = Dataset::synthetic(5, 4, 2, 16, 3);
        let cfg = ModelConfig::new(1, 4, 4);
        let (x0, _) = ds.batch(&cfg, 0);
        let (x5, _) = ds.batch(&cfg, 5); // 5*4 ≡ 0 mod 5 — same start
        assert_eq!(x0, x5);
    }
}
