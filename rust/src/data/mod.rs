//! Synthetic CIFAR-10-shaped dataset.
//!
//! The environment has no network access, so we generate a deterministic
//! dataset with CIFAR-10's shape: 50 000 train / 10 000 test points of
//! dimension 3 072 (zero-padded to 4 096 as in the paper), 10 classes.
//! The proof system's cost depends only on tensor shapes, never on pixel
//! values, so this substitution preserves every measured quantity
//! (DESIGN.md §Documented deviations). Class structure (a random class
//! centroid plus noise) gives the e2e example a learnable signal.

use crate::model::ModelConfig;
use crate::util::rng::Rng;

/// CIFAR-10 native dimension and its padded power of two.
pub const CIFAR_DIM: usize = 3072;
pub const CIFAR_DIM_PADDED: usize = 4096;
pub const CIFAR_CLASSES: usize = 10;
pub const CIFAR_TRAIN: usize = 50_000;

/// A quantized dataset: row-major points at scale 2^R plus integer labels.
#[derive(Clone, Debug)]
pub struct Dataset {
    pub dim: usize,
    pub points: Vec<Vec<i64>>,
    pub labels: Vec<usize>,
    pub num_classes: usize,
}

impl Dataset {
    /// Generate `n` points of dimension `dim` at scale 2^r_bits with `k`
    /// classes. Points are centroid + noise, centroids well-separated.
    pub fn synthetic(n: usize, dim: usize, k: usize, r_bits: u32, seed: u64) -> Self {
        let mut rng = Rng::seed_from_u64(seed);
        let scale = 1i64 << r_bits;
        // centroids with entries in [−scale/2, scale/2]
        let centroids: Vec<Vec<i64>> = (0..k)
            .map(|_| (0..dim).map(|_| rng.gen_i64(-scale / 2, scale / 2 + 1)).collect())
            .collect();
        let noise = scale / 4;
        let mut points = Vec::with_capacity(n);
        let mut labels = Vec::with_capacity(n);
        for i in 0..n {
            let c = i % k;
            let p: Vec<i64> = centroids[c]
                .iter()
                .map(|&v| (v + rng.gen_i64(-noise, noise + 1)).clamp(-scale + 1, scale - 1))
                .collect();
            points.push(p);
            labels.push(c);
        }
        Self {
            dim,
            points,
            labels,
            num_classes: k,
        }
    }

    /// CIFAR-10-shaped synthetic training set (small `n` for examples).
    pub fn cifar10_like(n: usize, r_bits: u32, seed: u64) -> Self {
        Self::synthetic(n, CIFAR_DIM, CIFAR_CLASSES, r_bits, seed)
    }

    pub fn len(&self) -> usize {
        self.points.len()
    }

    pub fn is_empty(&self) -> bool {
        self.points.is_empty()
    }

    /// Row indices of batch `idx` under the legacy wrapping schedule.
    pub fn batch_indices(&self, cfg: &ModelConfig, idx: usize) -> Vec<usize> {
        (0..cfg.batch).map(|i| (idx * cfg.batch + i) % self.len()).collect()
    }

    /// Assemble the given dataset rows as padded X, one-hot Y at scale 2^R
    /// for a model of config `cfg` — the row-indexed core every batch
    /// schedule ([`Self::batch`], [`BatchSampler`]) goes through.
    pub fn batch_at(&self, cfg: &ModelConfig, rows: &[usize]) -> (Vec<i64>, Vec<i64>) {
        let (b, d) = (cfg.batch, cfg.width);
        assert_eq!(rows.len(), b, "row count must match the batch size");
        assert!(d >= self.dim, "model width must cover data dim");
        let scale = cfg.scale();
        let mut x = vec![0i64; b * d];
        let mut y = vec![0i64; b * d];
        for (i, &j) in rows.iter().enumerate() {
            assert!(j < self.len(), "dataset row out of range");
            x[i * d..i * d + self.dim].copy_from_slice(&self.points[j]);
            y[i * d + self.labels[j]] = scale;
        }
        (x, y)
    }

    /// Assemble batch `idx` (wrapping) as padded X, one-hot Y at scale 2^R
    /// for a model of config `cfg`.
    pub fn batch(&self, cfg: &ModelConfig, idx: usize) -> (Vec<i64>, Vec<i64>) {
        self.batch_at(cfg, &self.batch_indices(cfg, idx))
    }

    /// Fraction of batch points classified correctly by arg-max of the last
    /// layer's rescaled output.
    pub fn batch_accuracy(&self, cfg: &ModelConfig, idx: usize, z_prime_last: &[i64]) -> f64 {
        let (b, d) = (cfg.batch, cfg.width);
        let mut correct = 0usize;
        for i in 0..b {
            let j = (idx * b + i) % self.len();
            let row = &z_prime_last[i * d..(i + 1) * d];
            let pred = (0..self.num_classes)
                .max_by_key(|&c| row[c])
                .unwrap_or(0);
            if pred == self.labels[j] {
                correct += 1;
            }
        }
        correct as f64 / b as f64
    }
}

/// Seeded without-replacement batch sampler: a Fisher–Yates-shuffled pass
/// over the dataset per epoch, reshuffling when fewer than a full batch
/// remains. Deterministic in (n, seed), so the coordinator's batch schedule
/// — and hence the provenance witness — reproduces exactly from the run
/// seed.
pub struct BatchSampler {
    order: Vec<usize>,
    pos: usize,
    rng: Rng,
}

impl BatchSampler {
    pub fn new(n: usize, seed: u64) -> Self {
        assert!(n >= 1, "cannot sample an empty dataset");
        let mut rng = Rng::seed_from_u64(seed);
        let mut order: Vec<usize> = (0..n).collect();
        rng.shuffle(&mut order);
        Self { order, pos: 0, rng }
    }

    /// The next `b` distinct row indices of the current epoch (`b` must not
    /// exceed the dataset size). An epoch's leftover shorter than `b` is
    /// folded into the next reshuffle.
    pub fn next_batch(&mut self, b: usize) -> Vec<usize> {
        assert!(
            b <= self.order.len(),
            "batch {b} exceeds dataset size {}",
            self.order.len()
        );
        if self.pos + b > self.order.len() {
            self.rng.shuffle(&mut self.order);
            self.pos = 0;
        }
        let out = self.order[self.pos..self.pos + b].to_vec();
        self.pos += b;
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_generation() {
        let a = Dataset::synthetic(100, 32, 10, 16, 7);
        let b = Dataset::synthetic(100, 32, 10, 16, 7);
        assert_eq!(a.points, b.points);
        assert_eq!(a.labels, b.labels);
        let c = Dataset::synthetic(100, 32, 10, 16, 8);
        assert_ne!(a.points, c.points);
    }

    #[test]
    fn values_in_scale_range() {
        let ds = Dataset::synthetic(50, 16, 4, 16, 1);
        let scale = 1i64 << 16;
        for p in &ds.points {
            assert_eq!(p.len(), 16);
            assert!(p.iter().all(|&v| v.abs() < scale));
        }
        assert!(ds.labels.iter().all(|&l| l < 4));
    }

    #[test]
    fn batch_layout() {
        let ds = Dataset::synthetic(10, 6, 3, 16, 2);
        let cfg = ModelConfig::new(1, 8, 4);
        let (x, y) = ds.batch(&cfg, 0);
        assert_eq!(x.len(), 4 * 8);
        // padding zeroed
        for i in 0..4 {
            assert_eq!(x[i * 8 + 6], 0);
            assert_eq!(x[i * 8 + 7], 0);
        }
        // one-hot Y rows sum to the scale
        for i in 0..4 {
            let s: i64 = y[i * 8..(i + 1) * 8].iter().sum();
            assert_eq!(s, cfg.scale());
        }
    }

    #[test]
    fn batch_at_matches_wrapping_batch() {
        let ds = Dataset::synthetic(10, 6, 3, 16, 2);
        let cfg = ModelConfig::new(1, 8, 4);
        let rows = ds.batch_indices(&cfg, 3);
        assert_eq!(rows, vec![12 % 10, 13 % 10, 14 % 10, 15 % 10]);
        assert_eq!(ds.batch_at(&cfg, &rows), ds.batch(&cfg, 3));
    }

    #[test]
    fn sampler_is_deterministic_and_covers_each_epoch() {
        let n = 12;
        let b = 4;
        let mut a = BatchSampler::new(n, 9);
        let mut c = BatchSampler::new(n, 9);
        let batches_a: Vec<Vec<usize>> = (0..6).map(|_| a.next_batch(b)).collect();
        let batches_c: Vec<Vec<usize>> = (0..6).map(|_| c.next_batch(b)).collect();
        assert_eq!(batches_a, batches_c, "same seed, same schedule");
        // one epoch (n/b batches) covers every row exactly once
        let mut seen: Vec<usize> = batches_a[..3].iter().flatten().copied().collect();
        seen.sort_unstable();
        assert_eq!(seen, (0..n).collect::<Vec<_>>(), "first epoch covers all rows");
        let mut seen2: Vec<usize> = batches_a[3..6].iter().flatten().copied().collect();
        seen2.sort_unstable();
        assert_eq!(seen2, (0..n).collect::<Vec<_>>(), "second epoch covers all rows");
        // a different seed yields a different order
        let mut d = BatchSampler::new(n, 10);
        let other: Vec<Vec<usize>> = (0..3).map(|_| d.next_batch(b)).collect();
        assert_ne!(batches_a[..3], other[..], "seed changes the schedule");
        // non-dividing batch size: the short tail triggers a reshuffle and
        // every draw still yields b distinct in-range rows
        let mut e = BatchSampler::new(10, 3);
        for _ in 0..7 {
            let batch = e.next_batch(4);
            assert_eq!(batch.len(), 4);
            let mut sorted = batch.clone();
            sorted.sort_unstable();
            sorted.dedup();
            assert_eq!(sorted.len(), 4, "rows within a batch are distinct");
            assert!(batch.iter().all(|&r| r < 10));
        }
    }

    #[test]
    fn batches_wrap() {
        let ds = Dataset::synthetic(5, 4, 2, 16, 3);
        let cfg = ModelConfig::new(1, 4, 4);
        let (x0, _) = ds.batch(&cfg, 0);
        let (x5, _) = ds.batch(&cfg, 5); // 5*4 ≡ 0 mod 5 — same start
        assert_eq!(x0, x5);
    }
}
