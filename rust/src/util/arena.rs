//! Flat scratch arena for prover-side tensor work.
//!
//! Per-step witness/aux generation used to allocate fresh `Vec<Fr>`s for
//! every (step, layer) pair — eq-tables, MLE fold buffers, expanded
//! integer tensors — churning the allocator T·L times per trace. An
//! [`FrArena`] owns one growable region and hands out zero-initialized
//! scratch slices; after the first step the region's capacity is warm and
//! every reuse is counted as `arena/bytes_reused`.

use crate::field::Fr;
use crate::telemetry::{self, Counter};

/// One reusable bump region of field elements.
#[derive(Default)]
pub struct FrArena {
    buf: Vec<Fr>,
}

impl FrArena {
    pub fn new() -> Self {
        Self::default()
    }

    /// Arena with capacity for `n` elements pre-reserved (so even the
    /// first scratch call of a sized workload avoids growth realloc).
    pub fn with_capacity(n: usize) -> Self {
        Self {
            buf: Vec::with_capacity(n),
        }
    }

    /// Run `f` over a zeroed scratch slice of `n` elements carved from the
    /// arena. The slice's lifetime is the call — the region is recycled by
    /// the next `scratch`, which is what makes it an arena and not an
    /// allocation.
    pub fn scratch<R>(&mut self, n: usize, f: impl FnOnce(&mut [Fr]) -> R) -> R {
        if self.buf.capacity() >= n {
            telemetry::count(
                Counter::ArenaBytesReused,
                (n * std::mem::size_of::<Fr>()) as u64,
            );
        }
        self.buf.clear();
        self.buf.resize(n, Fr::ZERO);
        f(&mut self.buf[..n])
    }

    /// Current capacity in bytes (high-water mark of all scratch sizes).
    pub fn capacity_bytes(&self) -> usize {
        self.buf.capacity() * std::mem::size_of::<Fr>()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scratch_is_zeroed_and_reused() {
        let mut arena = FrArena::new();
        let s = arena.scratch(16, |buf| {
            assert!(buf.iter().all(|v| *v == Fr::ZERO));
            buf[3] = Fr::from_u64(7);
            buf[3]
        });
        assert_eq!(s, Fr::from_u64(7));
        // second call sees zeroed memory again, smaller size fits capacity
        arena.scratch(8, |buf| {
            assert_eq!(buf.len(), 8);
            assert!(buf.iter().all(|v| *v == Fr::ZERO));
        });
        assert!(arena.capacity_bytes() >= 16 * std::mem::size_of::<Fr>());
    }

    #[test]
    fn with_capacity_prewarms() {
        let mut arena = FrArena::with_capacity(32);
        let cap = arena.capacity_bytes();
        arena.scratch(32, |_| {});
        assert_eq!(arena.capacity_bytes(), cap);
    }
}
