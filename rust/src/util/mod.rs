//! Support utilities: PRNG, benchmark harness, thread helpers, CLI parsing.
//!
//! These exist because the offline environment has no `rand`, `criterion`,
//! `rayon`, or `clap`; see DESIGN.md §Environment constraints.

pub mod arena;
pub mod bench;
pub mod cli;
pub mod rng;
pub mod threads;
