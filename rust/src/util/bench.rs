//! Minimal benchmark harness (criterion is unavailable offline).
//!
//! Used by the `[[bench]] harness = false` targets in `rust/benches/`.
//! Provides warmup + repeated timing with mean / median / min reporting and
//! a wall-clock budget so large parameter sweeps degrade gracefully
//! (matching the paper's "> 10³ s" timeout entries in Table 2).

use std::time::{Duration, Instant};

/// Statistics of one benchmark case.
///
/// Order statistics use the nearest-rank convention on the sorted samples:
/// `median` is `sorted[(n-1)/2]` (the lower median for even `n`) and `p95`
/// is `sorted[ceil(0.95·n)-1]`. With a single sample both equal that
/// sample and `stddev` is zero — every field is well-defined for `n ≥ 1`.
#[derive(Clone, Debug)]
pub struct Stats {
    pub iters: usize,
    pub mean: Duration,
    pub median: Duration,
    pub min: Duration,
    pub p95: Duration,
    pub stddev: Duration,
}

impl Stats {
    pub fn mean_secs(&self) -> f64 {
        self.mean.as_secs_f64()
    }

    /// JSON object fragment with all fields in seconds, e.g.
    /// `{"iters":8,"mean_s":0.5,...}` — splice into bench reports.
    pub fn to_json_fragment(&self) -> String {
        use crate::telemetry::json::Json;
        Json::obj(vec![
            ("iters", Json::Uint(self.iters as u64)),
            ("mean_s", Json::Num(self.mean.as_secs_f64())),
            ("median_s", Json::Num(self.median.as_secs_f64())),
            ("min_s", Json::Num(self.min.as_secs_f64())),
            ("p95_s", Json::Num(self.p95.as_secs_f64())),
            ("stddev_s", Json::Num(self.stddev.as_secs_f64())),
        ])
        .to_string()
    }
}

/// Time `f` up to `max_iters` times or until `budget` is exhausted
/// (always at least once). Returns per-iteration stats.
pub fn time_budgeted<F: FnMut()>(mut f: F, max_iters: usize, budget: Duration) -> Stats {
    let mut samples: Vec<Duration> = Vec::new();
    let start = Instant::now();
    for _ in 0..max_iters.max(1) {
        let t = Instant::now();
        f();
        samples.push(t.elapsed());
        if start.elapsed() > budget {
            break;
        }
    }
    samples.sort();
    let n = samples.len();
    let min = samples[0];
    let median = samples[(n - 1) / 2];
    let p95 = samples[(95 * n).div_ceil(100) - 1];
    let total: Duration = samples.iter().sum();
    let mean = total / n as u32;
    let mean_s = mean.as_secs_f64();
    let var = samples
        .iter()
        .map(|s| (s.as_secs_f64() - mean_s).powi(2))
        .sum::<f64>()
        / n as f64;
    Stats {
        iters: n,
        mean,
        median,
        min,
        p95,
        stddev: Duration::from_secs_f64(var.sqrt()),
    }
}

/// Time one run of `f`, returning its result and the elapsed time.
pub fn time_once<T, F: FnOnce() -> T>(f: F) -> (T, Duration) {
    let t = Instant::now();
    let out = f();
    (out, t.elapsed())
}

/// Pretty duration: "12.3 ms", "4.56 s".
pub fn fmt_dur(d: Duration) -> String {
    let s = d.as_secs_f64();
    if s >= 1.0 {
        format!("{s:.3} s")
    } else if s >= 1e-3 {
        format!("{:.3} ms", s * 1e3)
    } else if s >= 1e-6 {
        format!("{:.3} us", s * 1e6)
    } else {
        format!("{:.0} ns", s * 1e9)
    }
}

/// Simple fixed-width table printer for bench reports.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Self {
        Self {
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len());
        self.rows.push(cells);
    }

    pub fn print(&self) {
        print!("{}", self.render());
    }

    /// The formatted table as a string (one trailing newline) — reused by
    /// telemetry reports, which compose tables into larger documents.
    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        let line = |cells: &[String], out: &mut String| {
            for (i, c) in cells.iter().enumerate() {
                out.push_str(&format!("| {:w$} ", c, w = widths[i]));
            }
            out.push_str("|\n");
        };
        line(&self.headers, &mut out);
        let sep: Vec<String> = widths.iter().map(|w| "-".repeat(*w)).collect();
        line(&sep, &mut out);
        for row in &self.rows {
            line(row, &mut out);
        }
        out
    }
}

/// Parse `--flag value` style bench args (cargo bench passes through after `--`).
pub struct BenchArgs {
    args: Vec<String>,
}

impl BenchArgs {
    pub fn from_env() -> Self {
        Self {
            args: std::env::args().skip(1).collect(),
        }
    }

    pub fn has(&self, flag: &str) -> bool {
        self.args.iter().any(|a| a == flag)
    }

    pub fn get(&self, flag: &str) -> Option<&str> {
        self.args
            .iter()
            .position(|a| a == flag)
            .and_then(|i| self.args.get(i + 1))
            .map(|s| s.as_str())
    }

    /// `default` applies only when the flag is absent; a present-but-
    /// unparseable value is a fatal error (exit 2) — a typo'd sweep flag
    /// must not silently run the wrong grid.
    pub fn get_usize(&self, flag: &str, default: usize) -> usize {
        self.parse_or_die(flag, "a non-negative integer", default)
    }

    /// See [`Self::get_usize`] for the absent-vs-unparseable contract.
    pub fn get_f64(&self, flag: &str, default: f64) -> f64 {
        self.parse_or_die(flag, "a number", default)
    }

    fn parse_or_die<T: std::str::FromStr>(&self, flag: &str, expected: &str, default: T) -> T {
        match self.get(flag) {
            None => default,
            Some(s) => s.parse().unwrap_or_else(|_| {
                eprintln!("bench: invalid value {s:?} for {flag}: expected {expected}");
                std::process::exit(2);
            }),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timing_works() {
        let st = time_budgeted(
            || {
                std::hint::black_box(1 + 1);
            },
            16,
            Duration::from_secs(1),
        );
        assert!(st.iters >= 1 && st.iters <= 16);
        assert!(st.min <= st.median && st.median <= st.mean * 4);
    }

    #[test]
    fn fmt_scales() {
        assert!(fmt_dur(Duration::from_secs(2)).ends_with(" s"));
        assert!(fmt_dur(Duration::from_millis(5)).ends_with(" ms"));
        assert!(fmt_dur(Duration::from_micros(5)).ends_with(" us"));
    }

    #[test]
    fn single_sample_stats_are_well_defined() {
        let st = time_budgeted(
            || {
                std::hint::black_box(1 + 1);
            },
            1,
            Duration::from_secs(1),
        );
        assert_eq!(st.iters, 1);
        assert_eq!(st.median, st.min);
        assert_eq!(st.p95, st.min);
        assert_eq!(st.mean, st.min);
        assert_eq!(st.stddev, Duration::ZERO);
    }

    #[test]
    fn order_stats_use_nearest_rank() {
        // 20 samples: median = sorted[9] (lower median), p95 = sorted[18]
        let st = time_budgeted(
            || {
                std::thread::sleep(Duration::from_micros(10));
            },
            20,
            Duration::from_secs(10),
        );
        assert_eq!(st.iters, 20);
        assert!(st.min <= st.median && st.median <= st.p95);
    }

    #[test]
    fn stats_json_fragment_has_all_keys() {
        let st = time_budgeted(
            || {
                std::hint::black_box(1 + 1);
            },
            4,
            Duration::from_secs(1),
        );
        let frag = st.to_json_fragment();
        let v = crate::telemetry::json::Json::parse(&frag).expect("fragment parses");
        for key in ["iters", "mean_s", "median_s", "min_s", "p95_s", "stddev_s"] {
            assert!(v.get(key).is_some(), "missing {key}");
        }
        assert_eq!(v.get("iters").unwrap().as_u64(), Some(st.iters as u64));
    }

    #[test]
    fn table_renders_fixed_width() {
        let mut t = Table::new(&["a", "long_header"]);
        t.row(vec!["xxxx".into(), "1".into()]);
        let s = t.render();
        let lines: Vec<&str> = s.lines().collect();
        assert_eq!(lines.len(), 3);
        assert!(lines.iter().all(|l| l.len() == lines[0].len()));
        assert!(lines[0].contains("long_header"));
        assert!(lines[2].contains("xxxx"));
    }

    #[test]
    fn bench_args_default_only_when_absent() {
        let args = BenchArgs {
            args: vec!["--depth".into(), "4".into()],
        };
        assert_eq!(args.get_usize("--depth", 2), 4);
        assert_eq!(args.get_usize("--width", 8), 8);
        assert_eq!(args.get_f64("--budget", 1.5), 1.5);
        // unparseable values abort (exit 2) rather than silently defaulting;
        // that path is covered by inspection — it cannot run under the test
        // harness without killing the process.
    }
}
