//! Minimal benchmark harness (criterion is unavailable offline).
//!
//! Used by the `[[bench]] harness = false` targets in `rust/benches/`.
//! Provides warmup + repeated timing with mean / median / min reporting and
//! a wall-clock budget so large parameter sweeps degrade gracefully
//! (matching the paper's "> 10³ s" timeout entries in Table 2).

use std::time::{Duration, Instant};

/// Statistics of one benchmark case.
#[derive(Clone, Debug)]
pub struct Stats {
    pub iters: usize,
    pub mean: Duration,
    pub median: Duration,
    pub min: Duration,
}

impl Stats {
    pub fn mean_secs(&self) -> f64 {
        self.mean.as_secs_f64()
    }
}

/// Time `f` up to `max_iters` times or until `budget` is exhausted
/// (always at least once). Returns per-iteration stats.
pub fn time_budgeted<F: FnMut()>(mut f: F, max_iters: usize, budget: Duration) -> Stats {
    let mut samples: Vec<Duration> = Vec::new();
    let start = Instant::now();
    for _ in 0..max_iters.max(1) {
        let t = Instant::now();
        f();
        samples.push(t.elapsed());
        if start.elapsed() > budget {
            break;
        }
    }
    samples.sort();
    let min = samples[0];
    let median = samples[samples.len() / 2];
    let total: Duration = samples.iter().sum();
    let mean = total / samples.len() as u32;
    Stats {
        iters: samples.len(),
        mean,
        median,
        min,
    }
}

/// Time one run of `f`, returning its result and the elapsed time.
pub fn time_once<T, F: FnOnce() -> T>(f: F) -> (T, Duration) {
    let t = Instant::now();
    let out = f();
    (out, t.elapsed())
}

/// Pretty duration: "12.3 ms", "4.56 s".
pub fn fmt_dur(d: Duration) -> String {
    let s = d.as_secs_f64();
    if s >= 1.0 {
        format!("{s:.3} s")
    } else if s >= 1e-3 {
        format!("{:.3} ms", s * 1e3)
    } else if s >= 1e-6 {
        format!("{:.3} us", s * 1e6)
    } else {
        format!("{:.0} ns", s * 1e9)
    }
}

/// Simple fixed-width table printer for bench reports.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Self {
        Self {
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len());
        self.rows.push(cells);
    }

    pub fn print(&self) {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let line = |cells: &[String]| {
            let mut s = String::new();
            for (i, c) in cells.iter().enumerate() {
                s.push_str(&format!("| {:w$} ", c, w = widths[i]));
            }
            s.push('|');
            println!("{s}");
        };
        line(&self.headers);
        let sep: Vec<String> = widths.iter().map(|w| "-".repeat(*w)).collect();
        line(&sep);
        for row in &self.rows {
            line(row);
        }
    }
}

/// Parse `--flag value` style bench args (cargo bench passes through after `--`).
pub struct BenchArgs {
    args: Vec<String>,
}

impl BenchArgs {
    pub fn from_env() -> Self {
        Self {
            args: std::env::args().skip(1).collect(),
        }
    }

    pub fn has(&self, flag: &str) -> bool {
        self.args.iter().any(|a| a == flag)
    }

    pub fn get(&self, flag: &str) -> Option<&str> {
        self.args
            .iter()
            .position(|a| a == flag)
            .and_then(|i| self.args.get(i + 1))
            .map(|s| s.as_str())
    }

    pub fn get_usize(&self, flag: &str, default: usize) -> usize {
        self.get(flag).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    pub fn get_f64(&self, flag: &str, default: f64) -> f64 {
        self.get(flag).and_then(|s| s.parse().ok()).unwrap_or(default)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timing_works() {
        let st = time_budgeted(
            || {
                std::hint::black_box(1 + 1);
            },
            16,
            Duration::from_secs(1),
        );
        assert!(st.iters >= 1 && st.iters <= 16);
        assert!(st.min <= st.median && st.median <= st.mean * 4);
    }

    #[test]
    fn fmt_scales() {
        assert!(fmt_dur(Duration::from_secs(2)).ends_with(" s"));
        assert!(fmt_dur(Duration::from_millis(5)).ends_with(" ms"));
        assert!(fmt_dur(Duration::from_micros(5)).ends_with(" us"));
    }
}
