//! zkLanes: a persistent worker-pool runtime for data-parallel prover work.
//!
//! The seed version of this module spawned fresh `std::thread`s inside
//! `std::thread::scope` for every parallel call — tens of µs of spawn cost
//! per worker per call, paid again for every sumcheck round, every MSM
//! window split, every matmul. zkLanes replaces that with a pool of
//! `num_threads() - 1` workers spawned once on first use behind a
//! [`OnceLock`]; the calling thread itself acts as the final lane. Jobs are
//! lifetime-erased closures dispatched over a bounded channel; a
//! condvar-backed latch makes the dispatch *scoped* (the submitting call
//! does not return until every job has run), which is what lets jobs
//! borrow from the caller's stack safely.
//!
//! Determinism: none of the helpers here change *what* is computed, only
//! *where*. [`par_map`]/[`par_chunks_mut`] write disjoint output slots, and
//! [`par_reduce`] combines per-chunk partials in ascending chunk order —
//! so for the exact modular arithmetic of `Fr` (associative and
//! commutative) every thread count produces bit-identical results. See
//! DESIGN.md §perf "threading model".
//!
//! `ZKDL_THREADS` is re-read on every call, so setting it to `1` at any
//! point forces all helpers onto their sequential paths even if the pool
//! is already alive (the workers just idle). `ZKDL_THREADS=0` or unset
//! means "auto" (`available_parallelism`).

use std::cell::Cell;
use std::ops::Range;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::mpsc::{Receiver, SyncSender, TrySendError};
use std::sync::{mpsc, Condvar, Mutex, OnceLock};

use crate::telemetry::{self, Counter};

/// Number of parallel lanes to use (respects `ZKDL_THREADS`; `0` or a
/// non-numeric value falls through to `available_parallelism`). Re-read on
/// every call — tests flip it mid-process.
pub fn num_threads() -> usize {
    if let Ok(v) = std::env::var("ZKDL_THREADS") {
        if let Ok(n) = v.parse::<usize>() {
            if n >= 1 {
                return n;
            }
        }
    }
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
}

// ---------------------------------------------------------------------------
// Parallelism thresholds.
//
// The seed constants (`PAR_MIN_ITEMS = 8`, `PAR_MIN_ELEMS = 1024`) were
// tuned for per-call thread *spawn* cost, which the pool eliminated: a
// pooled dispatch is one boxed-closure allocation plus a channel send
// (~100ns), so the crossover moved by roughly an order of magnitude.
// Measured on the bench grid (T=16, depth=8, 8 lanes): splitting pays for
// itself once a call carries ≳2µs of work — ~2 hash-to-curve items or a
// few hundred field multiply-adds. Thresholds are now per-call-site
// *parameters* (`*_with` variants) so hot paths with known per-item cost
// can pick their own floor; the bare helpers keep pool-era defaults.
// ---------------------------------------------------------------------------

/// Pool-era default minimum item count before `par_map` splits. Call sites
/// with heavyweight items (curve derivations, Pippenger windows) can go as
/// low as 2 via [`par_map_with`].
pub const PAR_MIN_ITEMS: usize = 2;

/// Pool-era default minimum element count before `par_chunks_mut` splits.
/// Chunk callers (i64 matmuls, table doublings) do a few ns per element,
/// so ~256 elements is where a ~100ns dispatch stops mattering.
pub const PAR_MIN_ELEMS: usize = 256;

// ---------------------------------------------------------------------------
// The pool.
// ---------------------------------------------------------------------------

/// A pool job: a lifetime-erased closure. Only [`scope_run`] constructs
/// these, and it guarantees (by blocking on the latch) that the closure and
/// everything it borrows outlive the job's execution.
type Job = Box<dyn FnOnce() + Send + 'static>;

/// A borrow-capturing job as the public API sees it.
pub type ScopedJob<'scope> = Box<dyn FnOnce() + Send + 'scope>;

/// Bounded depth of the shared job queue. `scope_run` never blocks on a
/// full queue — it runs the job inline on the caller instead (counted as
/// `pool/queue_full`) — so this only bounds memory, not progress.
const QUEUE_CAP: usize = 1024;

struct Pool {
    tx: SyncSender<Job>,
    rx: Mutex<Receiver<Job>>,
    /// Workers spawned so far; grows lazily if `ZKDL_THREADS` rises
    /// mid-process (it never shrinks — surplus workers just idle).
    spawned: Mutex<usize>,
}

static POOL: OnceLock<Pool> = OnceLock::new();

thread_local! {
    /// Set once in each pool worker. A `scope_run` issued *from* a worker
    /// (nested parallelism) executes inline instead of re-entering the
    /// queue, which would deadlock the latch if every worker were waiting.
    static IN_POOL: Cell<bool> = const { Cell::new(false) };
}

/// The process-wide pool, spawned on first use and sized to
/// `num_threads() - 1` workers (the caller is the last lane).
fn pool() -> &'static Pool {
    let p = POOL.get_or_init(|| {
        let (tx, rx) = mpsc::sync_channel(QUEUE_CAP);
        Pool {
            tx,
            rx: Mutex::new(rx),
            spawned: Mutex::new(0),
        }
    });
    p.ensure_workers(num_threads().saturating_sub(1));
    p
}

impl Pool {
    fn ensure_workers(&'static self, want: usize) {
        let mut n = self.spawned.lock().unwrap();
        while *n < want {
            let id = *n;
            std::thread::Builder::new()
                .name(format!("zklane-{id}"))
                .spawn(move || self.worker_loop())
                .expect("spawn zklane worker");
            *n += 1;
        }
    }

    fn worker_loop(&self) {
        IN_POOL.with(|f| f.set(true));
        loop {
            // Hold the receiver lock only while dequeueing, never while
            // running the job.
            let job = match self.rx.lock().unwrap().recv() {
                Ok(job) => job,
                Err(_) => return, // sender dropped: process teardown
            };
            job();
        }
    }
}

/// Countdown latch: `scope_run` blocks on it until every job (pooled or
/// inline) has finished, and re-raises if any of them panicked.
struct Latch {
    state: Mutex<LatchState>,
    cv: Condvar,
}

struct LatchState {
    remaining: usize,
    panicked: bool,
}

impl Latch {
    fn new(remaining: usize) -> Self {
        Self {
            state: Mutex::new(LatchState {
                remaining,
                panicked: false,
            }),
            cv: Condvar::new(),
        }
    }

    fn done(&self, panicked: bool) {
        let mut s = self.state.lock().unwrap();
        s.remaining -= 1;
        s.panicked |= panicked;
        if s.remaining == 0 {
            self.cv.notify_all();
        }
    }

    /// Blocks until all jobs are done; returns whether any panicked.
    fn wait(&self) -> bool {
        let mut s = self.state.lock().unwrap();
        while s.remaining > 0 {
            s = self.cv.wait(s).unwrap();
        }
        s.panicked
    }
}

/// Run every job in `jobs` to completion, using the pool for all but the
/// first (the caller lane runs that one). Borrow-safe: does not return
/// until every job has finished, so jobs may capture references into the
/// caller's stack. Panics in any job are caught, the latch still drains,
/// and the panic is re-raised here after all jobs settle (so no borrow
/// outlives its owner even on unwind).
///
/// Sequential fallbacks: a single-lane configuration (`ZKDL_THREADS=1`),
/// a nested call from inside a pool worker, or a one-job list all execute
/// inline in order, touching neither the pool nor any counter.
pub fn scope_run(jobs: Vec<ScopedJob<'_>>) {
    if jobs.is_empty() {
        return;
    }
    if jobs.len() == 1 || num_threads() == 1 || IN_POOL.with(|f| f.get()) {
        for job in jobs {
            job();
        }
        return;
    }

    let latch = Latch::new(jobs.len());
    let latch_ref = &latch;
    let p = pool();
    let mut iter = jobs.into_iter();
    // The caller lane takes the first job; everything else goes to workers.
    let own = iter.next().unwrap();
    for job in iter {
        let wrapped: ScopedJob<'_> = Box::new(move || {
            let panicked = catch_unwind(AssertUnwindSafe(job)).is_err();
            latch_ref.done(panicked);
        });
        // SAFETY: the closure (and the borrows it captures, including
        // `latch_ref`) stays alive until `latch.wait()` below observes its
        // `done()`, so erasing the lifetime cannot let the job outlive its
        // borrows. This is the same contract `std::thread::scope` enforces,
        // implemented with a latch instead of a join.
        let wrapped: Job = unsafe {
            std::mem::transmute::<ScopedJob<'_>, Job>(wrapped)
        };
        match p.tx.try_send(wrapped) {
            Ok(()) => telemetry::count(Counter::PoolJobs, 1),
            Err(TrySendError::Full(job)) | Err(TrySendError::Disconnected(job)) => {
                // Bounded queue saturated (many concurrent top-level
                // scopes): degrade gracefully by running on the caller.
                telemetry::count(Counter::PoolQueueFull, 1);
                job();
            }
        }
    }
    let panicked_here = catch_unwind(AssertUnwindSafe(own)).is_err();
    latch.done(panicked_here);
    if latch.wait() {
        panic!("zklanes: a pooled job panicked");
    }
}

// ---------------------------------------------------------------------------
// Data-parallel helpers, all routed through `scope_run`.
// ---------------------------------------------------------------------------

/// Map `f` over `items` in parallel, preserving order, with the pool-era
/// default threshold. See [`par_map_with`].
pub fn par_map<T, U, F>(items: Vec<T>, f: F) -> Vec<U>
where
    T: Send,
    U: Send,
    F: Fn(T) -> U + Sync,
{
    par_map_with(PAR_MIN_ITEMS, items, f)
}

/// Map `f` over `items` in parallel, preserving order. Falls back to
/// sequential when one lane is configured or the input has at most
/// `min_items` items (per-call-site crossover; see the threshold notes at
/// the top of this module).
pub fn par_map_with<T, U, F>(min_items: usize, items: Vec<T>, f: F) -> Vec<U>
where
    T: Send,
    U: Send,
    F: Fn(T) -> U + Sync,
{
    let lanes = num_threads();
    if lanes == 1 || items.len() <= min_items {
        return items.into_iter().map(f).collect();
    }
    let n = items.len();
    let n_chunks = lanes.min(n);
    let chunk = n.div_ceil(n_chunks);
    let mut slots: Vec<Option<U>> = Vec::with_capacity(n);
    slots.resize_with(n, || None);
    // Move items into Option slots so each lane can take its chunk.
    let mut inputs: Vec<Option<T>> = items.into_iter().map(Some).collect();
    let f = &f;
    let jobs: Vec<ScopedJob<'_>> = inputs
        .chunks_mut(chunk)
        .zip(slots.chunks_mut(chunk))
        .map(|(in_chunk, out_chunk)| -> ScopedJob<'_> {
            Box::new(move || {
                for (inp, out) in in_chunk.iter_mut().zip(out_chunk.iter_mut()) {
                    *out = Some(f(inp.take().unwrap()));
                }
            })
        })
        .collect();
    scope_run(jobs);
    slots.into_iter().map(|o| o.unwrap()).collect()
}

/// Parallel index-range map: evaluates `f(i)` for i in 0..n, with the
/// default threshold.
pub fn par_map_indexed<U, F>(n: usize, f: F) -> Vec<U>
where
    U: Send,
    F: Fn(usize) -> U + Sync,
{
    par_map_with(PAR_MIN_ITEMS, (0..n).collect(), |i| f(i))
}

/// Run `f(chunk_index, chunk)` over mutable chunks of `data` in parallel
/// with the pool-era default threshold. See [`par_chunks_mut_with`].
pub fn par_chunks_mut<T, F>(data: &mut [T], chunk_size: usize, f: F)
where
    T: Send,
    F: Fn(usize, &mut [T]) + Sync,
{
    par_chunks_mut_with(PAR_MIN_ELEMS, data, chunk_size, f)
}

/// Run `f(chunk_index, chunk)` over mutable chunks of `data` in parallel.
/// Chunk indices and sizes are exactly those of `data.chunks_mut(chunk_size)`
/// regardless of lane count; consecutive chunks are *grouped* into at most
/// `num_threads()` jobs, so concurrency is capped at the lane count (the
/// seed version spawned one OS thread per chunk — a 2^20-point fixed-base
/// table with chunk 256 spawned 4096 threads; see the regression test).
/// Runs inline when one lane is configured, only one chunk exists, or the
/// data has fewer than `min_elems` elements.
pub fn par_chunks_mut_with<T, F>(min_elems: usize, data: &mut [T], chunk_size: usize, f: F)
where
    T: Send,
    F: Fn(usize, &mut [T]) + Sync,
{
    if data.is_empty() {
        return;
    }
    let chunk = chunk_size.max(1);
    let n_chunks = data.len().div_ceil(chunk);
    let lanes = num_threads();
    if lanes == 1 || n_chunks == 1 || data.len() < min_elems {
        for (i, c) in data.chunks_mut(chunk).enumerate() {
            f(i, c);
        }
        return;
    }
    let chunks_per_job = n_chunks.div_ceil(lanes);
    let f = &f;
    let jobs: Vec<ScopedJob<'_>> = data
        .chunks_mut(chunk * chunks_per_job)
        .enumerate()
        .map(|(job_i, segment)| -> ScopedJob<'_> {
            Box::new(move || {
                for (k, c) in segment.chunks_mut(chunk).enumerate() {
                    f(job_i * chunks_per_job + k, c);
                }
            })
        })
        .collect();
    scope_run(jobs);
}

/// Internal chunk width for [`par_tabulate`]: small enough to balance
/// lanes, large enough that the per-chunk closure call amortizes.
const TABULATE_CHUNK: usize = 1024;

/// Build `out[i] = f(i)` for `i in 0..n` across the pool. Every index is
/// written exactly once by exactly one lane, so the result is identical at
/// every lane count. `zero` seeds the buffer; below `min_elems` elements
/// the fill runs inline on the caller.
pub fn par_tabulate<T, F>(n: usize, min_elems: usize, zero: T, f: F) -> Vec<T>
where
    T: Clone + Send,
    F: Fn(usize) -> T + Sync,
{
    let mut out = vec![zero; n];
    par_chunks_mut_with(min_elems, &mut out, TABULATE_CHUNK, |ci, chunk| {
        let base = ci * TABULATE_CHUNK;
        for (k, slot) in chunk.iter_mut().enumerate() {
            *slot = f(base + k);
        }
    });
    out
}

/// Chunked map + associative reduce over the index range `0..n`.
///
/// The range is split into at most `num_threads()` contiguous chunks; each
/// lane folds its chunk with `map_chunk(range, identity.clone())`, and the
/// per-chunk partials are combined with `reduce` **in ascending chunk
/// order**. For an associative `reduce` this equals the sequential fold
/// for every lane count; for the commutative exact field arithmetic this
/// codebase feeds it (`Fr` sums), the result is bit-identical regardless
/// of chunk boundaries — which is what keeps proof artifacts byte-stable
/// across `ZKDL_THREADS` (pinned by `tests/parallel_determinism.rs`).
///
/// Sequential below `min_items` items (then exactly
/// `map_chunk(0..n, identity)` — property-tested against the pooled path).
pub fn par_reduce<A, M, R>(n: usize, min_items: usize, identity: A, map_chunk: M, reduce: R) -> A
where
    A: Clone + Send,
    M: Fn(Range<usize>, A) -> A + Sync,
    R: Fn(A, A) -> A,
{
    if n == 0 {
        return identity;
    }
    let lanes = num_threads();
    if lanes == 1 || n <= min_items.max(1) || IN_POOL.with(|f| f.get()) {
        return map_chunk(0..n, identity);
    }
    let n_chunks = lanes.min(n);
    let chunk = n.div_ceil(n_chunks);
    let mut partials: Vec<Option<A>> = Vec::with_capacity(n_chunks);
    partials.resize_with(n_chunks, || None);
    let map_chunk = &map_chunk;
    let id_ref = &identity;
    let jobs: Vec<ScopedJob<'_>> = partials
        .iter_mut()
        .enumerate()
        .map(|(ci, slot)| -> ScopedJob<'_> {
            let lo = ci * chunk;
            let hi = (lo + chunk).min(n);
            Box::new(move || {
                *slot = Some(map_chunk(lo..hi, id_ref.clone()));
            })
        })
        .collect();
    scope_run(jobs);
    let mut acc: Option<A> = None;
    for p in partials.into_iter().map(|p| p.unwrap()) {
        acc = Some(match acc {
            None => p,
            Some(a) => reduce(a, p),
        });
    }
    acc.unwrap_or(identity)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::HashSet;
    use std::sync::Mutex;
    use std::thread::ThreadId;

    #[test]
    fn par_map_preserves_order() {
        let v: Vec<usize> = (0..1000).collect();
        let out = par_map(v, |x| x * 2);
        assert_eq!(out, (0..1000).map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn small_inputs_fall_back_sequentially() {
        // at/below the threshold the sequential path must give identical
        // results
        let out = par_map_with(8, vec![1, 2, 3], |x| x + 1);
        assert_eq!(out, vec![2, 3, 4]);
        let mut v = vec![0u8; 10];
        par_chunks_mut(&mut v, 3, |i, c| c.iter_mut().for_each(|x| *x = i as u8 + 1));
        assert_eq!(v, vec![1, 1, 1, 2, 2, 2, 3, 3, 3, 4]);
    }

    #[test]
    fn par_chunks_mut_covers_all() {
        let mut v = vec![0u64; 977];
        par_chunks_mut(&mut v, 100, |i, chunk| {
            for c in chunk.iter_mut() {
                *c = i as u64 + 1;
            }
        });
        assert!(v.iter().all(|&x| x > 0));
    }

    #[test]
    fn par_chunks_mut_concurrency_is_capped_at_lane_count() {
        // Regression: the seed spawned one OS thread per *chunk*, so 4096
        // chunks meant 4096 threads. The pooled version must execute on at
        // most num_threads() distinct threads (workers + the caller).
        let threads: Mutex<HashSet<ThreadId>> = Mutex::new(HashSet::new());
        let mut v = vec![0u32; 1 << 16];
        par_chunks_mut(&mut v, 16, |i, chunk| {
            threads.lock().unwrap().insert(std::thread::current().id());
            for c in chunk.iter_mut() {
                *c = i as u32 + 1;
            }
        });
        assert!(v.iter().all(|&x| x > 0));
        let used = threads.lock().unwrap().len();
        assert!(
            used <= num_threads(),
            "used {used} threads for {} chunks with {} lanes",
            (1usize << 16) / 16,
            num_threads()
        );
    }

    #[test]
    fn par_tabulate_writes_every_index() {
        let v = par_tabulate(10_000, 1, 0usize, |i| i * 3 + 1);
        assert_eq!(v.len(), 10_000);
        for (i, &x) in v.iter().enumerate() {
            assert_eq!(x, i * 3 + 1);
        }
        assert!(par_tabulate(0, 1, 0u8, |_| 1).is_empty());
    }

    #[test]
    fn par_reduce_matches_sequential_fold() {
        let n = 100_000usize;
        let seq: u64 = (0..n as u64).map(|i| i.wrapping_mul(2654435761)).sum();
        let par = par_reduce(
            n,
            1,
            0u64,
            |r, acc: u64| {
                r.fold(acc, |a, i| {
                    a.wrapping_add((i as u64).wrapping_mul(2654435761))
                })
            },
            |a, b| a.wrapping_add(b),
        );
        assert_eq!(seq, par);
        // Empty range returns the identity untouched.
        assert_eq!(par_reduce(0, 1, 7u64, |_, a| a, |a, b| a + b), 7);
    }

    #[test]
    fn nested_scope_runs_inline_without_deadlock() {
        // A par_map whose body itself calls par_reduce: the inner call must
        // not wait on pool workers that are all busy running the outer one.
        let outer: Vec<u64> = par_map_with(
            0,
            (0..64u64).collect(),
            |i| par_reduce(256, 1, 0u64, |r, a: u64| r.fold(a, |x, j| x + j as u64 + i), |a, b| a + b),
        );
        for (i, &got) in outer.iter().enumerate() {
            let want: u64 = (0..256u64).map(|j| j + i as u64).sum();
            assert_eq!(got, want, "lane {i}");
        }
    }

    #[test]
    fn pooled_job_panic_propagates_after_drain() {
        let caught = std::panic::catch_unwind(|| {
            par_map_with(0, (0..64usize).collect(), |i| {
                if i == 17 {
                    panic!("boom");
                }
                i
            });
        });
        assert!(caught.is_err(), "panic in a pooled job must propagate");
        // The pool must still be usable afterwards.
        let out = par_map_with(0, (0..64usize).collect(), |i| i + 1);
        assert_eq!(out[63], 64);
    }
}
