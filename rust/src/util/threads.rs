//! Tiny data-parallel helpers over `std::thread::scope` (rayon substitute).

/// Number of worker threads to use (respects `ZKDL_THREADS`).
pub fn num_threads() -> usize {
    if let Ok(v) = std::env::var("ZKDL_THREADS") {
        if let Ok(n) = v.parse::<usize>() {
            return n.max(1);
        }
    }
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
}

/// Minimum item count before `par_map` spawns worker threads. Its call
/// sites all have heavyweight per-item work (a hash-to-curve derivation, a
/// Pippenger bucket window, a witness row batch), so below this count the
/// per-thread spawn cost (tens of µs) dominates the work being split.
pub const PAR_MIN_ITEMS: usize = 8;

/// Minimum element count before `par_chunks_mut` spawns. Chunk callers
/// (the i64 matmuls) do only a few ns per element, so the threshold is in
/// elements rather than chunks.
pub const PAR_MIN_ELEMS: usize = 1024;

/// Map `f` over `items` in parallel, preserving order.
/// Falls back to sequential when a single thread is available or the input
/// has at most [`PAR_MIN_ITEMS`] items, where spawn overhead would
/// dominate.
pub fn par_map<T, U, F>(items: Vec<T>, f: F) -> Vec<U>
where
    T: Send,
    U: Send,
    F: Fn(T) -> U + Sync,
{
    let n_threads = num_threads();
    if n_threads == 1 || items.len() <= PAR_MIN_ITEMS {
        return items.into_iter().map(f).collect();
    }
    let n = items.len();
    let chunk = n.div_ceil(n_threads.min(n));
    let mut slots: Vec<Option<U>> = Vec::with_capacity(n);
    slots.resize_with(n, || None);
    // Move items into Option slots so each worker can take its chunk.
    let mut inputs: Vec<Option<T>> = items.into_iter().map(Some).collect();
    let f = &f;
    std::thread::scope(|s| {
        for (in_chunk, out_chunk) in inputs.chunks_mut(chunk).zip(slots.chunks_mut(chunk)) {
            s.spawn(move || {
                for (inp, out) in in_chunk.iter_mut().zip(out_chunk.iter_mut()) {
                    *out = Some(f(inp.take().unwrap()));
                }
            });
        }
    });
    slots.into_iter().map(|o| o.unwrap()).collect()
}

/// Run `f(chunk_index, chunk)` over mutable chunks of `data` in parallel.
/// Runs inline (same guard as [`par_map`]) when only one chunk would be
/// spawned, a single thread is available, or the data is smaller than
/// [`PAR_MIN_ELEMS`].
pub fn par_chunks_mut<T, F>(data: &mut [T], chunk_size: usize, f: F)
where
    T: Send,
    F: Fn(usize, &mut [T]) + Sync,
{
    if data.is_empty() {
        return;
    }
    let chunk = chunk_size.max(1);
    let n_chunks = data.len().div_ceil(chunk);
    if num_threads() == 1 || n_chunks == 1 || data.len() < PAR_MIN_ELEMS {
        for (i, c) in data.chunks_mut(chunk).enumerate() {
            f(i, c);
        }
        return;
    }
    let f = &f;
    std::thread::scope(|s| {
        for (i, chunk) in data.chunks_mut(chunk).enumerate() {
            s.spawn(move || f(i, chunk));
        }
    });
}

/// Parallel index-range map: evaluates `f(i)` for i in 0..n.
pub fn par_map_indexed<U, F>(n: usize, f: F) -> Vec<U>
where
    U: Send,
    F: Fn(usize) -> U + Sync,
{
    par_map((0..n).collect(), |i| f(i))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn par_map_preserves_order() {
        let v: Vec<usize> = (0..1000).collect();
        let out = par_map(v, |x| x * 2);
        assert_eq!(out, (0..1000).map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn small_inputs_fall_back_sequentially() {
        // below PAR_MIN_ITEMS / PAR_MIN_ELEMS the sequential path must give
        // identical results
        let out = par_map(vec![1, 2, 3], |x| x + 1);
        assert_eq!(out, vec![2, 3, 4]);
        let mut v = vec![0u8; 10];
        par_chunks_mut(&mut v, 3, |i, c| {
            c.iter_mut().for_each(|x| *x = i as u8 + 1)
        });
        assert_eq!(v, vec![1, 1, 1, 2, 2, 2, 3, 3, 3, 4]);
    }

    #[test]
    fn par_chunks_mut_covers_all() {
        let mut v = vec![0u64; 977];
        par_chunks_mut(&mut v, 100, |i, chunk| {
            for c in chunk.iter_mut() {
                *c = i as u64 + 1;
            }
        });
        assert!(v.iter().all(|&x| x > 0));
    }
}
