//! Deterministic PRNG (xoshiro256** seeded via SplitMix64).
//!
//! The offline crate set has no `rand`; this is the standard xoshiro256**
//! generator — plenty for protocol randomness in tests/benches and for
//! synthetic data. Protocol challenges in the actual proofs come from the
//! Fiat–Shamir transcript, not from here.

/// xoshiro256** PRNG.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed via SplitMix64 so that similar seeds give unrelated streams.
    pub fn seed_from_u64(seed: u64) -> Self {
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9e3779b97f4a7c15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xbf58476d1ce4e5b9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94d049bb133111eb);
            z ^ (z >> 31)
        };
        Self {
            s: [next(), next(), next(), next()],
        }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in [0, bound) (with negligible modulo bias for small bounds).
    #[inline]
    pub fn gen_range(&mut self, bound: u64) -> u64 {
        assert!(bound > 0);
        self.next_u64() % bound
    }

    /// Uniform signed integer in [lo, hi).
    #[inline]
    pub fn gen_i64(&mut self, lo: i64, hi: i64) -> i64 {
        assert!(lo < hi);
        lo + self.gen_range((hi - lo) as u64) as i64
    }

    pub fn fill_bytes(&mut self, out: &mut [u8]) {
        for chunk in out.chunks_mut(8) {
            let v = self.next_u64().to_le_bytes();
            chunk.copy_from_slice(&v[..chunk.len()]);
        }
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, slice: &mut [T]) {
        for i in (1..slice.len()).rev() {
            let j = self.gen_range(i as u64 + 1) as usize;
            slice.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::seed_from_u64(1);
        let mut b = Rng::seed_from_u64(1);
        for _ in 0..10 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Rng::seed_from_u64(2);
        assert_ne!(a.next_u64(), c.next_u64());
    }

    #[test]
    fn range_bounds() {
        let mut r = Rng::seed_from_u64(3);
        for _ in 0..1000 {
            let v = r.gen_i64(-5, 5);
            assert!((-5..5).contains(&v));
            let u = r.gen_range(7);
            assert!(u < 7);
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::seed_from_u64(4);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>());
    }
}
