//! Deterministic PRNG (xoshiro256** seeded via SplitMix64).
//!
//! The offline crate set has no `rand`; this is the standard xoshiro256**
//! generator — plenty for protocol randomness in tests/benches and for
//! synthetic data. Protocol challenges in the actual proofs come from the
//! Fiat–Shamir transcript, not from here.

/// xoshiro256** PRNG.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed from process-level entropy. All four state words are filled
    /// (no collapse through a single u64), but they derive from std's
    /// per-thread `RandomState` keys (one ~128-bit OS-random seed plus a
    /// per-instance counter) mixed with the clock and an ASLR address —
    /// so the underlying entropy is ~128 bits and the words are not
    /// independent. Not a CSPRNG. Used for verifier-local batching
    /// coefficients, which only need to be unpredictable to whoever
    /// authored the proof bytes and never leave the process; Fiat–Shamir
    /// challenges never come from here. Swap in an OS CSPRNG if a
    /// stronger margin is ever needed.
    pub fn from_entropy() -> Self {
        use std::hash::{BuildHasher, Hasher};
        let word = |tag: u64| {
            let mut h = std::collections::hash_map::RandomState::new().build_hasher();
            h.write_u64(tag);
            h.finish()
        };
        let nanos = std::time::SystemTime::now()
            .duration_since(std::time::UNIX_EPOCH)
            .map(|d| d.as_nanos() as u64)
            .unwrap_or(0);
        let marker = 0u8;
        let addr = core::ptr::addr_of!(marker) as u64;
        let mut rng = Self {
            s: [
                word(1) ^ nanos,
                word(2) ^ addr,
                word(3) ^ nanos.rotate_left(32),
                word(4) ^ 0x7a6b646c, // "zkdl"
            ],
        };
        if rng.s.iter().all(|&x| x == 0) {
            rng.s[0] = 0x9e3779b97f4a7c15;
        }
        // decorrelate the raw source words before first use
        for _ in 0..8 {
            rng.next_u64();
        }
        rng
    }

    /// Derive an independent child generator carrying a fresh full-width
    /// 256-bit state drawn from this one (unlike re-seeding through a
    /// single u64, this preserves the parent's entropy width).
    pub fn split(&mut self) -> Self {
        let mut s = [
            self.next_u64(),
            self.next_u64(),
            self.next_u64(),
            self.next_u64(),
        ];
        if s.iter().all(|&x| x == 0) {
            s[0] = 0x9e3779b97f4a7c15;
        }
        let mut child = Self { s };
        // one round of mixing so parent and child streams decorrelate
        child.next_u64();
        child
    }

    /// Seed via SplitMix64 so that similar seeds give unrelated streams.
    pub fn seed_from_u64(seed: u64) -> Self {
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9e3779b97f4a7c15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xbf58476d1ce4e5b9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94d049bb133111eb);
            z ^ (z >> 31)
        };
        Self {
            s: [next(), next(), next(), next()],
        }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in [0, bound) (with negligible modulo bias for small bounds).
    #[inline]
    pub fn gen_range(&mut self, bound: u64) -> u64 {
        assert!(bound > 0);
        self.next_u64() % bound
    }

    /// Uniform signed integer in [lo, hi).
    #[inline]
    pub fn gen_i64(&mut self, lo: i64, hi: i64) -> i64 {
        assert!(lo < hi);
        lo + self.gen_range((hi - lo) as u64) as i64
    }

    pub fn fill_bytes(&mut self, out: &mut [u8]) {
        for chunk in out.chunks_mut(8) {
            let v = self.next_u64().to_le_bytes();
            chunk.copy_from_slice(&v[..chunk.len()]);
        }
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, slice: &mut [T]) {
        for i in (1..slice.len()).rev() {
            let j = self.gen_range(i as u64 + 1) as usize;
            slice.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::seed_from_u64(1);
        let mut b = Rng::seed_from_u64(1);
        for _ in 0..10 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Rng::seed_from_u64(2);
        assert_ne!(a.next_u64(), c.next_u64());
    }

    #[test]
    fn split_streams_are_deterministic_and_diverge() {
        let mut a1 = Rng::seed_from_u64(1);
        let mut a2 = Rng::seed_from_u64(1);
        let mut c1 = a1.split();
        let mut c2 = a2.split();
        for _ in 0..10 {
            assert_eq!(c1.next_u64(), c2.next_u64());
        }
        // successive splits of one parent give unrelated streams
        let mut d = a1.split();
        assert_ne!(c1.next_u64(), d.next_u64());
    }

    #[test]
    fn entropy_seeds_differ_across_calls() {
        let mut a = Rng::from_entropy();
        let mut b = Rng::from_entropy();
        assert_ne!(
            [a.next_u64(), a.next_u64()],
            [b.next_u64(), b.next_u64()]
        );
    }

    #[test]
    fn range_bounds() {
        let mut r = Rng::seed_from_u64(3);
        for _ in 0..1000 {
            let v = r.gen_i64(-5, 5);
            assert!((-5..5).contains(&v));
            let u = r.gen_range(7);
            assert!(u < 7);
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::seed_from_u64(4);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>());
    }
}
