//! Deterministic CSPRNG (ChaCha20 core, SplitMix64 seed expansion).
//!
//! The offline crate set has no `rand`; this is a self-contained ChaCha20
//! generator (djb variant: 64-bit block counter + 64-bit stream nonce),
//! pinned to the reference keystream by a known-answer test. ChaCha20 is a
//! cryptographic PRG, so the verifier-local batching coefficients of the
//! deferred verification engine inherit a real CSPRNG margin; Fiat–Shamir
//! challenges in the actual proofs still come from the transcript, not from
//! here.

/// The "expand 32-byte k" ChaCha constants.
const CHACHA_CONSTS: [u32; 4] = [0x6170_7865, 0x3320_646e, 0x7962_2d32, 0x6b20_6574];

#[inline(always)]
fn quarter_round(s: &mut [u32; 16], a: usize, b: usize, c: usize, d: usize) {
    s[a] = s[a].wrapping_add(s[b]);
    s[d] ^= s[a];
    s[d] = s[d].rotate_left(16);
    s[c] = s[c].wrapping_add(s[d]);
    s[b] ^= s[c];
    s[b] = s[b].rotate_left(12);
    s[a] = s[a].wrapping_add(s[b]);
    s[d] ^= s[a];
    s[d] = s[d].rotate_left(8);
    s[c] = s[c].wrapping_add(s[d]);
    s[b] ^= s[c];
    s[b] = s[b].rotate_left(7);
}

/// One 64-byte ChaCha20 block: 10 double rounds plus the feed-forward.
fn chacha20_block(key: &[u32; 8], counter: u64, nonce: u64) -> [u32; 16] {
    let mut s = [0u32; 16];
    s[..4].copy_from_slice(&CHACHA_CONSTS);
    s[4..12].copy_from_slice(key);
    s[12] = counter as u32;
    s[13] = (counter >> 32) as u32;
    s[14] = nonce as u32;
    s[15] = (nonce >> 32) as u32;
    let mut w = s;
    for _ in 0..10 {
        quarter_round(&mut w, 0, 4, 8, 12);
        quarter_round(&mut w, 1, 5, 9, 13);
        quarter_round(&mut w, 2, 6, 10, 14);
        quarter_round(&mut w, 3, 7, 11, 15);
        quarter_round(&mut w, 0, 5, 10, 15);
        quarter_round(&mut w, 1, 6, 11, 12);
        quarter_round(&mut w, 2, 7, 8, 13);
        quarter_round(&mut w, 3, 4, 9, 14);
    }
    for (wi, si) in w.iter_mut().zip(s.iter()) {
        *wi = wi.wrapping_add(*si);
    }
    w
}

/// ChaCha20-based PRNG.
#[derive(Clone, Debug)]
pub struct Rng {
    key: [u32; 8],
    nonce: u64,
    counter: u64,
    /// Buffered keystream of the current block, as 8 little-endian u64.
    buf: [u64; 8],
    /// Next unread index into `buf`; 8 means the buffer is exhausted.
    pos: usize,
}

impl Rng {
    fn from_key(key: [u32; 8], nonce: u64) -> Self {
        Self {
            key,
            nonce,
            counter: 0,
            buf: [0; 8],
            pos: 8,
        }
    }

    /// Seed from process-level entropy. The key words derive from std's
    /// per-thread `RandomState` keys (one ~128-bit OS-random seed plus a
    /// per-instance counter) mixed with the clock and an ASLR address, so
    /// the seed entropy is ~128 bits; the keystream expanding it is full
    /// ChaCha20. Used for verifier-local batching coefficients, which only
    /// need to be unpredictable to whoever authored the proof bytes and
    /// never leave the process.
    pub fn from_entropy() -> Self {
        use std::hash::{BuildHasher, Hasher};
        let word = |tag: u64| {
            let mut h = std::collections::hash_map::RandomState::new().build_hasher();
            h.write_u64(tag);
            h.finish()
        };
        let nanos = std::time::SystemTime::now()
            .duration_since(std::time::UNIX_EPOCH)
            .map(|d| d.as_nanos() as u64)
            .unwrap_or(0);
        let marker = 0u8;
        let addr = core::ptr::addr_of!(marker) as u64;
        let raw = [
            word(1) ^ nanos,
            word(2) ^ addr,
            word(3) ^ nanos.rotate_left(32),
            word(4) ^ 0x7a6b646c, // "zkdl"
        ];
        let mut key = [0u32; 8];
        for (i, r) in raw.iter().enumerate() {
            key[2 * i] = *r as u32;
            key[2 * i + 1] = (*r >> 32) as u32;
        }
        Self::from_key(key, word(5) ^ addr.rotate_left(17))
    }

    /// Derive an independent child generator keyed by 256 bits of this
    /// one's keystream — parent and child streams are computationally
    /// unrelated and the parent's full entropy width is preserved.
    pub fn split(&mut self) -> Self {
        let mut key = [0u32; 8];
        for i in 0..4 {
            let v = self.next_u64();
            key[2 * i] = v as u32;
            key[2 * i + 1] = (v >> 32) as u32;
        }
        let nonce = self.next_u64();
        Self::from_key(key, nonce)
    }

    /// Seed via SplitMix64 so that similar seeds give unrelated streams.
    pub fn seed_from_u64(seed: u64) -> Self {
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9e3779b97f4a7c15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xbf58476d1ce4e5b9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94d049bb133111eb);
            z ^ (z >> 31)
        };
        let mut key = [0u32; 8];
        for i in 0..4 {
            let v = next();
            key[2 * i] = v as u32;
            key[2 * i + 1] = (v >> 32) as u32;
        }
        Self::from_key(key, next())
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        if self.pos == 8 {
            let w = chacha20_block(&self.key, self.counter, self.nonce);
            self.counter = self.counter.wrapping_add(1);
            for i in 0..8 {
                self.buf[i] = (w[2 * i] as u64) | ((w[2 * i + 1] as u64) << 32);
            }
            self.pos = 0;
        }
        let v = self.buf[self.pos];
        self.pos += 1;
        v
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in [0, bound) (with negligible modulo bias for small bounds).
    #[inline]
    pub fn gen_range(&mut self, bound: u64) -> u64 {
        assert!(bound > 0);
        self.next_u64() % bound
    }

    /// Uniform signed integer in [lo, hi).
    #[inline]
    pub fn gen_i64(&mut self, lo: i64, hi: i64) -> i64 {
        assert!(lo < hi);
        lo + self.gen_range((hi - lo) as u64) as i64
    }

    pub fn fill_bytes(&mut self, out: &mut [u8]) {
        for chunk in out.chunks_mut(8) {
            let v = self.next_u64().to_le_bytes();
            chunk.copy_from_slice(&v[..chunk.len()]);
        }
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, slice: &mut [T]) {
        for i in (1..slice.len()).rev() {
            let j = self.gen_range(i as u64 + 1) as usize;
            slice.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chacha20_known_answer() {
        // Reference keystream for the all-zero key, nonce, and counter
        // (the classic ChaCha20 "TC1" vector); pins the block function to
        // the real cipher, not merely *a* deterministic permutation.
        let expected: [u8; 64] = [
            0x76, 0xb8, 0xe0, 0xad, 0xa0, 0xf1, 0x3d, 0x90, 0x40, 0x5d, 0x6a, 0xe5, 0x53, 0x86,
            0xbd, 0x28, 0xbd, 0xd2, 0x19, 0xb8, 0xa0, 0x8d, 0xed, 0x1a, 0xa8, 0x36, 0xef, 0xcc,
            0x8b, 0x77, 0x0d, 0xc7, 0xda, 0x41, 0x59, 0x7c, 0x51, 0x57, 0x48, 0x8d, 0x77, 0x24,
            0xe0, 0x3f, 0xb8, 0xd8, 0x4a, 0x37, 0x6a, 0x43, 0xb8, 0xf4, 0x15, 0x18, 0xa1, 0x1c,
            0xc3, 0x87, 0xb6, 0x69, 0xb2, 0xee, 0x65, 0x86,
        ];
        let mut rng = Rng::from_key([0u32; 8], 0);
        let mut out = [0u8; 64];
        rng.fill_bytes(&mut out);
        assert_eq!(out, expected);
    }

    #[test]
    fn deterministic() {
        let mut a = Rng::seed_from_u64(1);
        let mut b = Rng::seed_from_u64(1);
        for _ in 0..10 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Rng::seed_from_u64(2);
        assert_ne!(a.next_u64(), c.next_u64());
    }

    #[test]
    fn split_streams_are_deterministic_and_diverge() {
        let mut a1 = Rng::seed_from_u64(1);
        let mut a2 = Rng::seed_from_u64(1);
        let mut c1 = a1.split();
        let mut c2 = a2.split();
        for _ in 0..10 {
            assert_eq!(c1.next_u64(), c2.next_u64());
        }
        // successive splits of one parent give unrelated streams
        let mut d = a1.split();
        assert_ne!(c1.next_u64(), d.next_u64());
    }

    #[test]
    fn entropy_seeds_differ_across_calls() {
        let mut a = Rng::from_entropy();
        let mut b = Rng::from_entropy();
        assert_ne!(
            [a.next_u64(), a.next_u64()],
            [b.next_u64(), b.next_u64()]
        );
    }

    #[test]
    fn range_bounds() {
        let mut r = Rng::seed_from_u64(3);
        for _ in 0..1000 {
            let v = r.gen_i64(-5, 5);
            assert!((-5..5).contains(&v));
            let u = r.gen_range(7);
            assert!(u < 7);
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::seed_from_u64(4);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>());
    }
}
