//! Hand-rolled CLI argument parsing (clap is unavailable offline).
//!
//! Supports `zkdl <subcommand> --key value --flag` invocations.

use std::collections::HashMap;

/// Parsed command line: a subcommand plus `--key value` options and flags.
/// Options may repeat (`--in a --in b`): every value is kept in order;
/// [`Cli::get`] returns the last, [`Cli::get_all`] returns all of them.
#[derive(Debug, Default, Clone)]
pub struct Cli {
    pub subcommand: Option<String>,
    pub options: HashMap<String, Vec<String>>,
    pub flags: Vec<String>,
    pub positional: Vec<String>,
}

impl Cli {
    pub fn parse(args: impl Iterator<Item = String>) -> Self {
        let mut cli = Cli::default();
        let args: Vec<String> = args.collect();
        let mut i = 0;
        while i < args.len() {
            let a = &args[i];
            if let Some(key) = a.strip_prefix("--") {
                // `--key value` if the next token is not another option,
                // otherwise a bare flag.
                if i + 1 < args.len() && !args[i + 1].starts_with("--") {
                    cli.options
                        .entry(key.to_string())
                        .or_default()
                        .push(args[i + 1].clone());
                    i += 2;
                    continue;
                }
                cli.flags.push(key.to_string());
            } else if cli.subcommand.is_none() {
                cli.subcommand = Some(a.clone());
            } else {
                cli.positional.push(a.clone());
            }
            i += 1;
        }
        cli
    }

    pub fn from_env() -> Self {
        Self::parse(std::env::args().skip(1))
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    /// Last value of an option (single-value callers).
    pub fn get(&self, name: &str) -> Option<&str> {
        self.options
            .get(name)
            .and_then(|vs| vs.last())
            .map(|s| s.as_str())
    }

    /// All values of a repeated option, in the order given (empty if
    /// absent) — e.g. `verify-trace --in a.zkp --in b.zkp`.
    pub fn get_all(&self, name: &str) -> Vec<&str> {
        self.options
            .get(name)
            .map(|vs| vs.iter().map(|s| s.as_str()).collect())
            .unwrap_or_default()
    }

    pub fn get_usize(&self, name: &str, default: usize) -> usize {
        self.get(name).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    pub fn get_u64(&self, name: &str, default: u64) -> u64 {
        self.get(name).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    pub fn get_f64(&self, name: &str, default: f64) -> f64 {
        self.get(name).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    pub fn get_str<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Cli {
        Cli::parse(s.split_whitespace().map(|x| x.to_string()))
    }

    #[test]
    fn basic_parse() {
        let c = parse("prove --width 256 --bs 32 --parallel extra");
        assert_eq!(c.subcommand.as_deref(), Some("prove"));
        assert_eq!(c.get_usize("width", 0), 256);
        assert_eq!(c.get_usize("bs", 0), 32);
        // `--parallel extra`: "extra" does not start with --, so it binds as value
        assert_eq!(c.get("parallel"), Some("extra"));
    }

    #[test]
    fn trailing_flag() {
        let c = parse("bench --full");
        assert!(c.flag("full"));
        assert_eq!(c.subcommand.as_deref(), Some("bench"));
    }

    #[test]
    fn repeated_options_collect_in_order() {
        let c = parse("verify-trace --in a.zkp --in b.zkp --in c.zkp --depth 2");
        assert_eq!(c.get_all("in"), vec!["a.zkp", "b.zkp", "c.zkp"]);
        // `get` keeps the last value for single-value callers
        assert_eq!(c.get("in"), Some("c.zkp"));
        assert_eq!(c.get_all("depth"), vec!["2"]);
        assert!(c.get_all("missing").is_empty());
    }

    #[test]
    fn defaults() {
        let c = parse("x");
        assert_eq!(c.get_usize("missing", 42), 42);
        assert_eq!(c.get_str("s", "d"), "d");
    }
}
