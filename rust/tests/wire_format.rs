//! Wire-format tests: proptest-style randomized encode/decode roundtrips
//! for every proof component, canonical re-encoding of real proofs, and a
//! golden-bytes test pinning the versioned header so silent format drift is
//! caught at CI time.

use zkdl::aggregate::{prove_trace, prove_trace_chained, verify_trace, TraceKey};
use zkdl::curve::{G1Affine, G1};
use zkdl::data::Dataset;
use zkdl::ipa::IpaProof;
use zkdl::model::{ModelConfig, Weights};
use zkdl::sumcheck::SumcheckProof;
use zkdl::util::rng::Rng;
use zkdl::wire::{
    decode_step_proof, decode_trace_proof, encode_step_proof, encode_trace_proof, FromWire,
    ToWire, WireReader, WireWriter, MAGIC, VERSION,
};
use zkdl::witness::native::compute_witness;
use zkdl::zkdl::{prove_step, verify_step, ProofMode, ProverKey};
use zkdl::zkrelu::{Protocol1Msg, ValidityProof};
use zkdl::Fr;

fn roundtrip_bytes<T: ToWire + FromWire>(v: &T) -> Vec<u8> {
    let mut w = WireWriter::new();
    w.put(v);
    let bytes = w.finish();
    let mut r = WireReader::new(&bytes);
    let back: T = r.get().expect("decodes");
    r.expect_end().expect("fully consumed");
    let mut w2 = WireWriter::new();
    w2.put(&back);
    let bytes2 = w2.finish();
    assert_eq!(bytes, bytes2, "re-encoding must be byte-identical");
    bytes
}

fn random_point(rng: &mut Rng) -> G1Affine {
    G1::random(rng).to_affine()
}

fn random_ipa(rng: &mut Rng, rounds: usize) -> IpaProof {
    IpaProof {
        l: (0..rounds).map(|_| random_point(rng)).collect(),
        r: (0..rounds).map(|_| random_point(rng)).collect(),
        a: Fr::random(rng),
        b: Fr::random(rng),
        blind: Fr::random(rng),
    }
}

#[test]
fn randomized_scalar_and_point_roundtrips() {
    let mut rng = Rng::seed_from_u64(0x31e1);
    for _ in 0..50 {
        roundtrip_bytes(&Fr::random(&mut rng));
        roundtrip_bytes(&random_point(&mut rng));
    }
    roundtrip_bytes(&G1Affine::IDENTITY);
    roundtrip_bytes(&Fr::ZERO);
}

#[test]
fn randomized_sumcheck_proof_roundtrips() {
    let mut rng = Rng::seed_from_u64(0x31e2);
    for _ in 0..20 {
        let num_vars = 1 + (rng.gen_range(6) as usize);
        let degree = 1 + (rng.gen_range(3) as usize);
        let proof = SumcheckProof {
            round_evals: (0..num_vars)
                .map(|_| (0..degree + 1).map(|_| Fr::random(&mut rng)).collect())
                .collect(),
            degree,
            num_vars,
        };
        roundtrip_bytes(&proof);
    }
}

#[test]
fn randomized_ipa_proof_roundtrips() {
    let mut rng = Rng::seed_from_u64(0x31e3);
    for _ in 0..20 {
        let rounds = rng.gen_range(8) as usize;
        roundtrip_bytes(&random_ipa(&mut rng, rounds));
    }
}

#[test]
fn randomized_protocol1_and_validity_roundtrips() {
    let mut rng = Rng::seed_from_u64(0x31e4);
    for i in 0..20usize {
        let msg = Protocol1Msg {
            com_b_ip: random_point(&mut rng),
            com_sign_prime: (i % 2 == 0).then(|| random_point(&mut rng)),
        };
        roundtrip_bytes(&msg);
        let vp = ValidityProof {
            ipa: random_ipa(&mut rng, 1 + (i % 5)),
        };
        roundtrip_bytes(&vp);
    }
}

#[test]
fn golden_header_bytes() {
    // Pins the envelope layout of VERSION 6 (zkData: trace envelope gains
    // an optional batch-provenance payload and the transcript absorbs a
    // provenance flag for every trace). If this test fails, the wire
    // format changed: bump `wire::VERSION` and update the constants here.
    let cfg = ModelConfig::new(2, 8, 4);
    let wits = trace_witnesses(cfg, 1, 0x601d);
    let tk = TraceKey::setup(cfg, 1);
    let mut rng = Rng::seed_from_u64(7);
    let proof = prove_trace(&tk, &wits, &mut rng);
    let bytes = encode_trace_proof(&cfg, &proof);
    let expected_header: [u8; 32] = [
        b'Z', b'K', b'D', b'L', // magic
        0x06, 0x00, // version 6
        0x02, 0x00, // kind: trace
        0x02, 0x00, 0x00, 0x00, // depth 2
        0x08, 0x00, 0x00, 0x00, // width 8
        0x04, 0x00, 0x00, 0x00, // batch 4
        0x10, 0x00, 0x00, 0x00, // r_bits 16
        0x20, 0x00, 0x00, 0x00, // q_bits 32
        0x08, 0x00, 0x00, 0x00, // lr_shift 8
    ];
    assert_eq!(&bytes[..32], expected_header.as_slice());
    assert_eq!(MAGIC.as_slice(), b"ZKDL".as_slice());
    assert_eq!(VERSION, 6);
    // step-count field follows the header
    assert_eq!(&bytes[32..36], 1u32.to_le_bytes().as_slice());
}

#[test]
fn rejects_v5_artifacts_as_unsupported() {
    // the v6 transcript absorbs a provenance flag for EVERY trace, so a
    // v5 artifact can decode but never verify — reject it up front
    let cfg = ModelConfig::new(2, 8, 4);
    let wits = trace_witnesses(cfg, 1, 0x0506);
    let tk = TraceKey::setup(cfg, 1);
    let mut rng = Rng::seed_from_u64(46);
    let proof = prove_trace(&tk, &wits, &mut rng);
    let mut bytes = encode_trace_proof(&cfg, &proof);
    bytes[4] = 0x05;
    bytes[5] = 0x00;
    let err = decode_trace_proof(&bytes).expect_err("v5 must not decode");
    assert!(
        format!("{err:#}").contains("unsupported version"),
        "rejected as unsupported, not misparsed: {err:#}"
    );
}

#[test]
fn rejects_v4_chained_artifacts_as_unsupported() {
    // a v4 chain payload has no rule tag / shift table / state
    // commitments: decoding it under v5 rules would misparse, so the
    // envelope version check must reject it outright
    let cfg = ModelConfig::new(2, 8, 4);
    let wits = trace_witnesses(cfg, 3, 0x0405);
    let tk = TraceKey::setup(cfg, 3);
    let mut rng = Rng::seed_from_u64(45);
    let proof = prove_trace_chained(&tk, &wits, &mut rng).expect("chains");
    let mut bytes = encode_trace_proof(&cfg, &proof);
    bytes[4] = 0x04; // rewrite the version field to v4
    bytes[5] = 0x00;
    let err = decode_trace_proof(&bytes).expect_err("v4 must not decode");
    assert!(
        format!("{err:#}").contains("unsupported version"),
        "rejected as unsupported, not misparsed: {err:#}"
    );
}

#[test]
fn compressed_points_halve_serialized_point_size() {
    // v3+ serializes points compressed: the wire cost of one point is the
    // 4-byte vector prefix amortized out — spot-check via a bare roundtrip
    let mut rng = Rng::seed_from_u64(0x31e9);
    let p = random_point(&mut rng);
    let mut w = WireWriter::new();
    w.put(&p);
    assert_eq!(w.finish().len(), 32);
}

fn trace_witnesses(cfg: ModelConfig, steps: usize, seed: u64) -> Vec<zkdl::witness::StepWitness> {
    let mut rng = Rng::seed_from_u64(seed);
    let ds = Dataset::synthetic(64, cfg.width / 2, 4, cfg.r_bits, seed ^ 0x77);
    let mut weights = Weights::init(cfg, &mut rng);
    let mut out = Vec::with_capacity(steps);
    for step in 0..steps {
        let (x, y) = ds.batch(&cfg, step);
        let wit = compute_witness(cfg, &x, &y, &weights);
        weights.apply_update(&wit.weight_grads());
        out.push(wit);
    }
    out
}

#[test]
fn step_proof_disk_roundtrip_verifies() {
    let cfg = ModelConfig::new(2, 8, 4);
    let wits = trace_witnesses(cfg, 1, 0xd15c);
    let pk = ProverKey::setup(cfg);
    let mut rng = Rng::seed_from_u64(21);
    let proof = prove_step(&pk, &wits[0], ProofMode::Parallel, &mut rng);
    let bytes = encode_step_proof(&cfg, &proof);
    let (cfg2, decoded) = decode_step_proof(&bytes).expect("decodes");
    assert_eq!(cfg, cfg2);
    // canonical: re-encoding the decoded proof is byte-identical
    assert_eq!(bytes, encode_step_proof(&cfg2, &decoded));
    verify_step(&ProverKey::setup(cfg2), &decoded).expect("decoded proof verifies");
}

#[test]
fn trace_proof_disk_roundtrip_verifies() {
    let cfg = ModelConfig::new(2, 8, 4);
    let wits = trace_witnesses(cfg, 2, 0xd15d);
    let tk = TraceKey::setup(cfg, 2);
    let mut rng = Rng::seed_from_u64(22);
    let proof = prove_trace(&tk, &wits, &mut rng);
    let bytes = encode_trace_proof(&cfg, &proof);
    let (cfg2, decoded) = decode_trace_proof(&bytes).expect("decodes");
    assert_eq!(cfg, cfg2);
    assert_eq!(bytes, encode_trace_proof(&cfg2, &decoded));
    // out-of-process verification: keys rebuilt from the file alone
    let tk2 = TraceKey::setup(cfg2, decoded.steps);
    verify_trace(&tk2, &decoded).expect("decoded trace verifies");
}

#[test]
fn chained_trace_proof_disk_roundtrip_verifies() {
    let cfg = ModelConfig::new(2, 8, 4);
    let wits = trace_witnesses(cfg, 3, 0xd15e);
    let tk = TraceKey::setup(cfg, 3);
    let mut rng = Rng::seed_from_u64(24);
    let proof = prove_trace_chained(&tk, &wits, &mut rng).expect("witnesses chain");
    let bytes = encode_trace_proof(&cfg, &proof);
    let (cfg2, decoded) = decode_trace_proof(&bytes).expect("decodes");
    assert_eq!(cfg, cfg2);
    assert!(decoded.chain.is_some());
    assert_eq!(bytes, encode_trace_proof(&cfg2, &decoded));
    let tk2 = TraceKey::setup(cfg2, decoded.steps);
    verify_trace(&tk2, &decoded).expect("decoded chained trace verifies");
    // a chained proof with a boundary-evaluation count mismatch must not
    // decode
    let mut truncated = proof.clone();
    truncated.chain.as_mut().unwrap().v_w.pop();
    let bad = encode_trace_proof(&cfg, &truncated);
    assert!(decode_trace_proof(&bad).is_err());
    // ... nor one whose shift table is shorter than its boundary count
    let mut truncated = proof.clone();
    truncated.chain.as_mut().unwrap().lr_shifts.pop();
    let bad = encode_trace_proof(&cfg, &truncated);
    assert!(decode_trace_proof(&bad).is_err());
    // ... nor a schedule whose digit budget exceeds the provable 64
    let mut wide = proof;
    wide.chain.as_mut().unwrap().lr_shifts[0] = 60; // S = 76
    let bad = encode_trace_proof(&cfg, &wide);
    assert!(decode_trace_proof(&bad).is_err());
}

#[test]
fn momentum_chained_trace_proof_disk_roundtrip_verifies() {
    use zkdl::aggregate::prove_trace_chained_with;
    use zkdl::update::{LrSchedule, UpdateRule};
    use zkdl::witness::native::rule_witness_chain;
    let cfg = ModelConfig::new(2, 8, 4);
    let rule = UpdateRule::momentum_default();
    let sched = LrSchedule::StepDecay {
        base: cfg.lr_shift,
        period: 1,
        max: cfg.lr_shift + 1,
    };
    let ds = Dataset::synthetic(64, cfg.width / 2, 4, cfg.r_bits, 0x3d1);
    let wits = rule_witness_chain(cfg, &rule, &sched, &ds, 3, 0xd15f);
    let tk = TraceKey::setup(cfg, 3);
    let mut rng = Rng::seed_from_u64(25);
    let table = sched.window_table(0, 2);
    let proof =
        prove_trace_chained_with(&tk, &wits, &rule, &table, &mut rng).expect("momentum chains");
    let bytes = encode_trace_proof(&cfg, &proof);
    let (cfg2, decoded) = decode_trace_proof(&bytes).expect("decodes");
    let chain = decoded.chain.as_ref().expect("chain present");
    assert_eq!(chain.rule, rule);
    assert_eq!(chain.lr_shifts, table);
    assert_eq!(chain.com_state.len(), 1);
    assert_eq!(chain.com_state[0].len(), 3 * cfg.depth);
    // canonical: re-encoding the decoded proof is byte-identical
    assert_eq!(bytes, encode_trace_proof(&cfg2, &decoded));
    verify_trace(&TraceKey::setup(cfg2, decoded.steps), &decoded)
        .expect("decoded momentum trace verifies");
    // a state-commitment count mismatch must not decode
    let mut bad_proof = proof;
    bad_proof.chain.as_mut().unwrap().com_state[0].pop();
    let bad = encode_trace_proof(&cfg, &bad_proof);
    assert!(decode_trace_proof(&bad).is_err());
}

#[test]
fn decoder_rejects_malformed_envelopes() {
    let cfg = ModelConfig::new(2, 8, 4);
    let wits = trace_witnesses(cfg, 1, 0xbad);
    let tk = TraceKey::setup(cfg, 1);
    let mut rng = Rng::seed_from_u64(23);
    let proof = prove_trace(&tk, &wits, &mut rng);
    let bytes = encode_trace_proof(&cfg, &proof);

    // bad magic
    let mut bad = bytes.clone();
    bad[0] ^= 0xff;
    assert!(decode_trace_proof(&bad).is_err());
    // unsupported version
    let mut bad = bytes.clone();
    bad[4] = 0x63;
    assert!(decode_trace_proof(&bad).is_err());
    // wrong kind for the decoder entry point
    assert!(decode_step_proof(&bytes).is_err());
    // truncation
    assert!(decode_trace_proof(&bytes[..bytes.len() - 1]).is_err());
    // trailing garbage
    let mut bad = bytes.clone();
    bad.push(0);
    assert!(decode_trace_proof(&bad).is_err());
}
