//! Cross-module integration tests: the full pipeline from data through
//! witness (both sources) to proof and verification, plus adversarial
//! cases that cut across module boundaries.

use std::path::Path;
use zkdl::coordinator::{train_and_prove, TrainOptions};
use zkdl::data::Dataset;
use zkdl::hash::HashFn;
use zkdl::merkle::{verify_membership, MerkleTree};
use zkdl::model::{ModelConfig, Weights};
use zkdl::runtime::{StepRuntime, WitnessSource};
use zkdl::util::rng::Rng;
use zkdl::witness::native::compute_witness;
use zkdl::zkdl::{prove_step, verify_step, ProofMode, ProverKey};

fn artifact_dir() -> &'static Path {
    Path::new("artifacts")
}

#[test]
fn full_pipeline_pjrt_witness_to_verified_proof() {
    let cfg = ModelConfig::new(2, 64, 16);
    let Ok(rt) = StepRuntime::load(artifact_dir(), cfg) else {
        eprintln!("skipping (no artifact; run `make artifacts`)");
        return;
    };
    let mut rng = Rng::seed_from_u64(1);
    let ds = Dataset::synthetic(64, 32, 10, cfg.r_bits, 1);
    let (x, y) = ds.batch(&cfg, 0);
    let w = Weights::init(cfg, &mut rng);
    let wit = rt.compute_witness(&x, &y, &w).expect("pjrt witness");
    wit.validate().expect("relations hold");
    let pk = ProverKey::setup(cfg);
    let proof = prove_step(&pk, &wit, ProofMode::Parallel, &mut rng);
    verify_step(&pk, &proof).expect("verifies");
}

#[test]
fn per_step_proofs_are_oblivious_to_optimizer_state() {
    // the zkOptim rule state (momentum accumulator) is chain-level
    // statement, not per-step witness: a momentum run's steps prove and
    // verify with the ordinary per-step argument, byte-identically to the
    // same tensors with the state stripped
    use zkdl::update::{LrSchedule, UpdateRule};
    use zkdl::witness::native::rule_witness_chain;
    let cfg = ModelConfig::new(2, 8, 4);
    let ds = Dataset::synthetic(32, 4, 4, cfg.r_bits, 3);
    let wits = rule_witness_chain(
        cfg,
        &UpdateRule::momentum_default(),
        &LrSchedule::Constant(cfg.lr_shift),
        &ds,
        2,
        0x1f2e,
    );
    assert!(!wits[1].opt_state.is_empty(), "momentum state attached");
    let pk = ProverKey::setup(cfg);
    let proof = prove_step(&pk, &wits[1], ProofMode::Parallel, &mut Rng::seed_from_u64(4));
    verify_step(&pk, &proof).expect("momentum step verifies per-step");
    let mut stripped = wits[1].clone();
    stripped.opt_state.clear();
    let proof2 = prove_step(&pk, &stripped, ProofMode::Parallel, &mut Rng::seed_from_u64(4));
    assert_eq!(
        zkdl::wire::encode_step_proof(&cfg, &proof),
        zkdl::wire::encode_step_proof(&cfg, &proof2),
        "state tensors do not leak into the per-step argument"
    );
}

#[test]
fn proof_rejects_witness_with_wrong_relu() {
    // forge a witness where one ReLU output is wrong but the decomposition
    // ranges still hold: the Hadamard/stacking checks must catch it
    let cfg = ModelConfig::new(2, 8, 4);
    let mut rng = Rng::seed_from_u64(2);
    let ds = Dataset::synthetic(16, 4, 4, cfg.r_bits, 2);
    let (x, y) = ds.batch(&cfg, 0);
    let w = Weights::init(cfg, &mut rng);
    let mut wit = compute_witness(cfg, &x, &y, &w);
    // flip one activation: A[0] := A[0] + 1 (violates (2))
    if let Some(a) = wit.layers[0].a.as_mut() {
        a[0] += 1;
    }
    assert!(wit.validate().is_err());
    let pk = ProverKey::setup(cfg);
    let proof = prove_step(&pk, &wit, ProofMode::Parallel, &mut rng);
    assert!(
        verify_step(&pk, &proof).is_err(),
        "tampered ReLU output must not verify"
    );
}

#[test]
fn proof_rejects_forged_sign_bits() {
    // a malicious trainer flips a sign bit to keep a negative activation:
    // aux ranges stay valid but relations (2)/(3) break
    let cfg = ModelConfig::new(2, 8, 4);
    let mut rng = Rng::seed_from_u64(3);
    let ds = Dataset::synthetic(16, 4, 4, cfg.r_bits, 3);
    let (x, y) = ds.batch(&cfg, 0);
    let w = Weights::init(cfg, &mut rng);
    let mut wit = compute_witness(cfg, &x, &y, &w);
    let aux = &mut wit.layers[0].z_aux;
    let i = aux.sign.iter().position(|&s| s == 1).unwrap_or(0);
    aux.sign[i] = 1 - aux.sign[i];
    let pk = ProverKey::setup(cfg);
    let proof = prove_step(&pk, &wit, ProofMode::Parallel, &mut rng);
    assert!(verify_step(&pk, &proof).is_err());
}

#[test]
fn proof_rejects_wrong_gradient() {
    let cfg = ModelConfig::new(2, 8, 4);
    let mut rng = Rng::seed_from_u64(4);
    let ds = Dataset::synthetic(16, 4, 4, cfg.r_bits, 4);
    let (x, y) = ds.batch(&cfg, 0);
    let w = Weights::init(cfg, &mut rng);
    let mut wit = compute_witness(cfg, &x, &y, &w);
    wit.layers[1].g_w[3] += 1; // violates (34)
    let pk = ProverKey::setup(cfg);
    let proof = prove_step(&pk, &wit, ProofMode::Parallel, &mut rng);
    assert!(verify_step(&pk, &proof).is_err());
}

#[test]
fn native_and_pjrt_sources_prove_identically_sized_proofs() {
    let cfg = ModelConfig::new(2, 8, 4);
    let mut rng = Rng::seed_from_u64(5);
    let ds = Dataset::synthetic(16, 4, 4, cfg.r_bits, 5);
    let (x, y) = ds.batch(&cfg, 0);
    let w = Weights::init(cfg, &mut rng);
    let native = compute_witness(cfg, &x, &y, &w);
    let src = WitnessSource::auto(artifact_dir(), cfg);
    let other = src.compute_witness(&x, &y, &w).unwrap();
    let pk = ProverKey::setup(cfg);
    let p1 = prove_step(&pk, &native, ProofMode::Parallel, &mut rng);
    let p2 = prove_step(&pk, &other, ProofMode::Parallel, &mut rng);
    verify_step(&pk, &p1).unwrap();
    verify_step(&pk, &p2).unwrap();
    assert_eq!(p1.size_bytes(), p2.size_bytes());
}

#[test]
fn coordinator_with_membership_audit() {
    // the end-to-end story: train with proofs, then answer a copyright query
    let cfg = ModelConfig::new(2, 8, 4);
    let ds = Dataset::synthetic(32, 4, 4, cfg.r_bits, 6);
    let opts = TrainOptions {
        steps: 2,
        prove_every: 1,
        ..Default::default()
    };
    let report = train_and_prove(cfg, &ds, artifact_dir(), &opts).expect("train");
    assert!(report.steps.iter().all(|s| s.proof_bytes > 0));

    // commit the training points and build the audit tree
    let ck = zkdl::commit::CommitKey::setup(b"itest/data", 4);
    let coms: Vec<Vec<u8>> = ds
        .points
        .iter()
        .map(|p| {
            let frs: Vec<zkdl::Fr> = p.iter().map(|&v| zkdl::Fr::from_i64(v)).collect();
            ck.commit_deterministic(&frs).to_affine().to_bytes().to_vec()
        })
        .collect();
    let hash = HashFn::Sha256;
    let tree = MerkleTree::build(hash, &coms);
    let queries = vec![hash.hash(&coms[0])];
    let proof = tree.prove(&queries);
    verify_membership(hash, &tree.root, &queries, &proof).expect("audit verifies");
    assert_eq!(proof.included.len(), 1);
}

#[test]
fn sequential_and_parallel_agree_on_acceptance() {
    let cfg = ModelConfig::new(3, 8, 4);
    let mut rng = Rng::seed_from_u64(7);
    let ds = Dataset::synthetic(16, 4, 4, cfg.r_bits, 7);
    let (x, y) = ds.batch(&cfg, 0);
    let w = Weights::init(cfg, &mut rng);
    let wit = compute_witness(cfg, &x, &y, &w);
    let pk = ProverKey::setup(cfg);
    for mode in [ProofMode::Parallel, ProofMode::Sequential] {
        let proof = prove_step(&pk, &wit, mode, &mut rng);
        verify_step(&pk, &proof).unwrap_or_else(|e| panic!("{} failed: {e:#}", mode.name()));
    }
}
