//! One-MSM verification engine: cross-module tests.
//!
//! * property test: `MsmAccumulator` agrees with the naive eager per-
//!   equation computation on random instances;
//! * batch soundness: a batch with exactly one tampered proof is rejected
//!   (no cross-proof cancellation) while the same proofs verify
//!   individually;
//! * the wire → batch-verify flow the `verify-trace --in a --in b` CLI
//!   verb uses.

use zkdl::aggregate::{
    ensure_same_root, prove_trace, prove_trace_chained, prove_trace_chained_with,
    prove_trace_provenance, verify_trace, verify_trace_accum, verify_traces_batch,
    verify_traces_batch_report, TraceKey, TraceProof,
};
use zkdl::curve::accum::MsmAccumulator;
use zkdl::curve::G1;
use zkdl::data::Dataset;
use zkdl::model::{ModelConfig, Weights};
use zkdl::provenance::ProverDataset;
use zkdl::telemetry::failure::{failure_class, VerifyFailureClass};
use zkdl::update::{LrSchedule, UpdateRule};
use zkdl::util::rng::Rng;
use zkdl::witness::native::compute_witness;
use zkdl::witness::StepWitness;
use zkdl::Fr;

fn witness_chain(cfg: ModelConfig, steps: usize, seed: u64) -> Vec<StepWitness> {
    let mut rng = Rng::seed_from_u64(seed);
    let ds = Dataset::synthetic(64, cfg.width / 2, 4, cfg.r_bits, seed ^ 0x77);
    let mut weights = Weights::init(cfg, &mut rng);
    let mut out = Vec::with_capacity(steps);
    for step in 0..steps {
        let (x, y) = ds.batch(&cfg, step);
        let wit = compute_witness(cfg, &x, &y, &weights);
        wit.validate().expect("witness valid");
        weights.apply_update(&wit.weight_grads());
        out.push(wit);
    }
    out
}

/// The accumulator's verdict must equal the conjunction of naive eager
/// per-equation checks, over random instances with and without violations.
#[test]
fn accumulator_agrees_with_naive_eager_computation() {
    for seed in 0..8u64 {
        let mut r = Rng::seed_from_u64(0x9a9a ^ seed);
        let n_eq = 1 + (seed as usize % 4);
        // equations as explicit (scalar, point) term lists
        let mut equations: Vec<Vec<(Fr, G1)>> = Vec::new();
        let mut all_hold = true;
        for eq in 0..n_eq {
            let mut terms: Vec<(Fr, G1)> = (0..3)
                .map(|_| (Fr::random(&mut r), G1::random(&mut r)))
                .collect();
            // close the equation: append the negated sum so it holds…
            let sum: G1 = terms
                .iter()
                .map(|(s, p)| p.mul(s))
                .fold(G1::IDENTITY, |a, b| a + b);
            terms.push((-Fr::ONE, sum));
            // …except when this seed/equation is chosen to be violated
            if seed % 3 == 0 && eq == 0 {
                terms.push((Fr::ONE, G1::random(&mut r)));
                all_hold = false;
            }
            equations.push(terms);
        }

        // naive eager evaluation
        let naive_ok = equations.iter().all(|terms| {
            terms
                .iter()
                .map(|(s, p)| p.mul(s))
                .fold(G1::IDENTITY, |a, b| a + b)
                .is_identity()
        });
        assert_eq!(naive_ok, all_hold);

        // deferred: all equations, one MSM
        let mut sr = Rng::seed_from_u64(seed);
        let mut acc = MsmAccumulator::from_rng(&mut sr);
        for terms in &equations {
            acc.begin_equation();
            for (s, p) in terms {
                acc.push_proj(*s, p);
            }
        }
        assert_eq!(acc.flush(), naive_ok, "seed {seed}");
        assert_eq!(acc.flushes(), 1);

        // eager-mode accumulator (one MSM per equation) agrees too
        let mut sr2 = Rng::seed_from_u64(seed ^ 1);
        let mut eager = MsmAccumulator::eager_from_rng(&mut sr2);
        for terms in &equations {
            eager.begin_equation();
            for (s, p) in terms {
                eager.push_proj(*s, p);
            }
        }
        assert_eq!(eager.flush(), naive_ok, "eager seed {seed}");
        assert_eq!(eager.flushes(), n_eq);
    }
}

/// The CLI flow: persist trace proofs to wire bytes, decode, batch-verify
/// with one MSM; a single tampered member breaks the batch while the
/// others still verify individually.
#[test]
fn wire_roundtrip_batch_verification_and_tamper_soundness() {
    let cfg = ModelConfig::new(2, 8, 4);
    let tk = TraceKey::setup(cfg, 2);
    let mut rng = Rng::seed_from_u64(0xeb);
    let a = prove_trace(&tk, &witness_chain(cfg, 2, 1), &mut rng);
    let b = prove_trace(&tk, &witness_chain(cfg, 2, 2), &mut rng);

    // wire roundtrip, as the CLI's multi `--in` path does
    let decode = |p: &TraceProof| -> (ModelConfig, TraceProof) {
        let bytes = zkdl::wire::encode_trace_proof(&cfg, p);
        zkdl::wire::decode_trace_proof(&bytes).expect("decodes")
    };
    let (cfg_a, da) = decode(&a);
    let (_, db) = decode(&b);
    assert_eq!(cfg_a, cfg);

    let mut vrng = Rng::seed_from_u64(3);
    verify_traces_batch(&[(&tk, &da), (&tk, &db)], &mut vrng).expect("good batch verifies");

    // exactly one tampered member — only the aggregate MSM can catch a
    // folded-scalar tamper, and random ρ-scaling must keep it visible
    let mut bad = db.clone();
    bad.openings[1].blind += Fr::ONE;
    verify_trace(&tk, &da).expect("member A verifies individually");
    assert!(verify_trace(&tk, &bad).is_err(), "tampered member fails alone");
    for seed in [4u64, 5, 6] {
        let mut vrng = Rng::seed_from_u64(seed);
        assert!(
            verify_traces_batch(&[(&tk, &da), (&tk, &bad)], &mut vrng).is_err(),
            "tampered batch must fail (seed {seed})"
        );
    }
}

/// One accumulator across heterogeneous proofs (different trace keys):
/// still exactly one MSM, still accepted.
#[test]
fn heterogeneous_trace_batch_shares_one_msm() {
    let cfg = ModelConfig::new(2, 8, 4);
    let tk1 = TraceKey::setup(cfg, 1);
    let tk2 = TraceKey::setup(cfg, 2);
    let mut rng = Rng::seed_from_u64(0x77);
    let p1 = prove_trace(&tk1, &witness_chain(cfg, 1, 7), &mut rng);
    let p2 = prove_trace(&tk2, &witness_chain(cfg, 2, 8), &mut rng);

    let mut seed = Rng::seed_from_u64(9);
    let mut acc = MsmAccumulator::from_rng(&mut seed);
    acc.set_scale(Fr::from_u64(3));
    verify_trace_accum(&tk1, &p1, &mut acc).expect("defer 1");
    acc.set_scale(Fr::from_u64(5));
    verify_trace_accum(&tk2, &p2, &mut acc).expect("defer 2");
    assert_eq!(acc.flushes(), 0, "nothing flushed until the end");
    assert!(acc.flush(), "heterogeneous batch verifies");
    assert_eq!(acc.flushes(), 1, "one MSM total");

    let mut vrng = Rng::seed_from_u64(10);
    verify_traces_batch(&[(&tk1, &p1), (&tk2, &p2)], &mut vrng).expect("public API agrees");
}

/// Mixed update rules inside one batch: an unchained trace, an SGD-chained
/// trace, and a momentum-chained trace (distinct update keys, distinct
/// validity layouts) all defer into ONE accumulator and one MSM.
#[test]
fn mixed_rule_trace_batch_shares_one_msm() {
    let cfg = ModelConfig::new(2, 8, 4);
    let tk = TraceKey::setup(cfg, 3);
    let mut rng = Rng::seed_from_u64(0x88);
    let plain = prove_trace(&tk, &witness_chain(cfg, 3, 11), &mut rng);
    let sgd = prove_trace_chained(&tk, &zkdl::witness::native::sgd_witness_chain(
        cfg,
        &Dataset::synthetic(64, cfg.width / 2, 4, cfg.r_bits, 0x99),
        3,
        12,
    ), &mut rng)
    .expect("sgd chains");
    let rule = UpdateRule::momentum_default();
    let sched = LrSchedule::Constant(cfg.lr_shift);
    let m_wits = zkdl::witness::native::rule_witness_chain(
        cfg,
        &rule,
        &sched,
        &Dataset::synthetic(64, cfg.width / 2, 4, cfg.r_bits, 0x9a),
        3,
        13,
    );
    let momentum =
        prove_trace_chained_with(&tk, &m_wits, &rule, &sched.window_table(0, 2), &mut rng)
            .expect("momentum chains");

    let mut seed = Rng::seed_from_u64(14);
    let mut acc = MsmAccumulator::from_rng(&mut seed);
    for proof in [&plain, &sgd, &momentum] {
        acc.set_scale(Fr::random_nonzero(&mut seed));
        verify_trace_accum(&tk, proof, &mut acc).expect("defer");
    }
    assert_eq!(acc.flushes(), 0);
    assert!(acc.flush(), "mixed-rule batch verifies with one MSM");
    assert_eq!(acc.flushes(), 1);

    let mut vrng = Rng::seed_from_u64(15);
    verify_traces_batch(
        &[(&tk, &plain), (&tk, &sgd), (&tk, &momentum)],
        &mut vrng,
    )
    .expect("public batch API agrees");
}

// ---------------------------------------------------------------------------
// zkFlight: wire-layer failure classes, per-proof batch reports, root policy
// ---------------------------------------------------------------------------

/// Decode rejections carry `wire-decode`, except a bad version which gets
/// the more specific `version-unsupported` (attach-once: inner class wins).
#[test]
fn wire_rejections_carry_decode_and_version_classes() {
    let cfg = ModelConfig::new(2, 8, 4);
    let tk = TraceKey::setup(cfg, 2);
    let mut rng = Rng::seed_from_u64(0xf0);
    let proof = prove_trace(&tk, &witness_chain(cfg, 2, 20), &mut rng);
    let bytes = zkdl::wire::encode_trace_proof(&cfg, &proof);

    // flipped magic
    let mut bad = bytes.clone();
    bad[0] ^= 0xff;
    let err = zkdl::wire::decode_trace_proof(&bad).expect_err("bad magic decodes");
    assert_eq!(failure_class(&err), Some(VerifyFailureClass::WireDecode), "{err:#}");

    // truncated artifact
    let err =
        zkdl::wire::decode_trace_proof(&bytes[..bytes.len() / 2]).expect_err("truncated decodes");
    assert_eq!(failure_class(&err), Some(VerifyFailureClass::WireDecode), "{err:#}");

    // future version (bytes 4..6, little-endian, after the 4-byte magic)
    let mut bad = bytes.clone();
    let future = (zkdl::wire::VERSION + 1).to_le_bytes();
    bad[4..6].copy_from_slice(&future);
    let err = zkdl::wire::decode_trace_proof(&bad).expect_err("future version decodes");
    assert_eq!(
        failure_class(&err),
        Some(VerifyFailureClass::VersionUnsupported),
        "{err:#}"
    );
}

/// A rejected batch re-verifies members individually and pins the failure
/// on the tampered index with its class; accepted members stay accepted.
#[test]
fn batch_report_attributes_failure_to_the_tampered_member() {
    let cfg = ModelConfig::new(2, 8, 4);
    let tk = TraceKey::setup(cfg, 2);
    let mut rng = Rng::seed_from_u64(0xf1);
    let a = prove_trace(&tk, &witness_chain(cfg, 2, 21), &mut rng);
    let b = prove_trace(&tk, &witness_chain(cfg, 2, 22), &mut rng);

    // all-good batch: one report, every entry accepted, no batch error
    let mut vrng = Rng::seed_from_u64(30);
    let report = verify_traces_batch_report(&[(&tk, &a), (&tk, &b)], &mut vrng);
    assert!(report.all_accepted());
    assert!(report.entries.iter().all(|e| e.failure_class.is_none()));

    // one member's blind shifted: only the aggregate MSM sees it, and the
    // report must pin it on index 1 with the msm-final-check class
    let mut bad = b.clone();
    bad.openings[1].blind += Fr::ONE;
    let mut vrng = Rng::seed_from_u64(31);
    let report = verify_traces_batch_report(&[(&tk, &a), (&tk, &bad)], &mut vrng);
    assert!(!report.all_accepted());
    assert!(report.batch_error.is_some());
    assert!(report.entries[0].accepted, "honest member stays accepted");
    assert!(!report.entries[1].accepted);
    assert_eq!(
        report.entries[1].failure_class,
        Some(VerifyFailureClass::MsmFinalCheck),
        "{:?}",
        report.entries[1].error
    );
}

/// `--require-same-root` policy: root-less proofs never conflict, two
/// provenance proofs pinning different datasets reject with `root-mismatch`.
#[test]
fn mixed_root_batches_are_rejected_by_policy() {
    let cfg = ModelConfig::new(2, 8, 4);
    let tk = TraceKey::setup(cfg, 2);
    let mut rng = Rng::seed_from_u64(0xf2);

    let make_prov = |seed: u64, rng: &mut Rng| -> TraceProof {
        let ds = Dataset::synthetic(24, cfg.width / 2, 4, cfg.r_bits, seed);
        let wits = zkdl::witness::native::sgd_witness_chain(cfg, &ds, 2, seed);
        let pd = ProverDataset::build(&ds, &cfg).expect("dataset commits");
        prove_trace_provenance(&tk, &wits, &pd, rng).expect("rows open")
    };
    let prov_a = make_prov(0xaa, &mut rng);
    let prov_b = make_prov(0xbb, &mut rng);
    let plain = prove_trace(&tk, &witness_chain(cfg, 2, 23), &mut rng);

    // same root twice + a root-less member: fine
    ensure_same_root(&[&prov_a, &plain, &prov_a]).expect("consistent batch passes");
    ensure_same_root(&[&plain, &plain]).expect("root-less batch passes");

    // two different endorsed datasets in one batch: policy rejection
    let err = ensure_same_root(&[&prov_a, &plain, &prov_b]).expect_err("mixed roots pass");
    assert_eq!(failure_class(&err), Some(VerifyFailureClass::RootMismatch), "{err:#}");
}
