//! zkData end-to-end tests: provenance traces round-trip through the wire
//! format and verify; every artifact-level tamper class — swapped dataset
//! statement, forged claims, stripped or grafted payloads — is rejected;
//! and the endorsement bridge ties the artifact root to the leaf set.

use zkdl::aggregate::{
    prove_trace, prove_trace_chained_provenance_with, prove_trace_provenance, verify_trace,
    TraceKey,
};
use zkdl::data::Dataset;
use zkdl::model::ModelConfig;
use zkdl::provenance::{verify_dataset_endorsement, ProverDataset};
use zkdl::telemetry::failure::{failure_class, VerifyFailureClass};
use zkdl::update::UpdateRule;
use zkdl::util::rng::Rng;
use zkdl::wire::{decode_trace_proof, encode_trace_proof};
use zkdl::witness::native::sgd_witness_chain;
use zkdl::witness::StepWitness;
use zkdl::Fr;

fn setup(steps: usize, seed: u64) -> (ModelConfig, Dataset, Vec<StepWitness>, ProverDataset) {
    let cfg = ModelConfig::new(2, 8, 4);
    let ds = Dataset::synthetic(24, cfg.width / 2, 4, cfg.r_bits, seed ^ 0x77);
    let wits = sgd_witness_chain(cfg, &ds, steps, seed);
    let pd = ProverDataset::build(&ds, &cfg).expect("dataset commits");
    (cfg, ds, wits, pd)
}

#[test]
fn provenance_trace_disk_roundtrip_verifies() {
    let (cfg, _, wits, pd) = setup(2, 0xd160);
    let tk = TraceKey::setup(cfg, 2);
    let mut rng = Rng::seed_from_u64(40);
    let proof = prove_trace_provenance(&tk, &wits, &pd, &mut rng).expect("rows open");
    let bytes = encode_trace_proof(&cfg, &proof);
    let (cfg2, decoded) = decode_trace_proof(&bytes).expect("decodes");
    assert_eq!(cfg, cfg2);
    let prov = decoded.provenance.as_ref().expect("payload survives");
    assert_eq!(prov.dataset.root, pd.commitment.root);
    assert_eq!(prov.dataset.n_rows, 24);
    // canonical: re-encoding the decoded proof is byte-identical
    assert_eq!(bytes, encode_trace_proof(&cfg2, &decoded));
    // out-of-process verification: keys rebuilt from the file alone
    verify_trace(&TraceKey::setup(cfg2, decoded.steps), &decoded)
        .expect("decoded provenance trace verifies");
    // ... and the endorsement bridge ties the artifact's root to the
    // released leaf set + dataset commitment
    verify_dataset_endorsement(&pd.leaves, &prov.dataset.root, &prov.dataset.com_d)
        .expect("endorsement checks out");
}

#[test]
fn chained_provenance_trace_disk_roundtrip_verifies() {
    let (cfg, _, wits, pd) = setup(3, 0xd161);
    let tk = TraceKey::setup(cfg, 3);
    let mut rng = Rng::seed_from_u64(41);
    let shifts = vec![cfg.lr_shift; 2];
    let proof =
        prove_trace_chained_provenance_with(&tk, &wits, &UpdateRule::Sgd, &shifts, &pd, &mut rng)
            .expect("chains and opens");
    assert!(proof.chain.is_some() && proof.provenance.is_some());
    let bytes = encode_trace_proof(&cfg, &proof);
    let (cfg2, decoded) = decode_trace_proof(&bytes).expect("decodes");
    assert_eq!(bytes, encode_trace_proof(&cfg2, &decoded));
    verify_trace(&TraceKey::setup(cfg2, decoded.steps), &decoded)
        .expect("decoded chained provenance trace verifies");
}

#[test]
fn stripped_provenance_payload_is_rejected() {
    // removing the payload flips the transcript's provenance flag: the
    // remaining (otherwise valid) trace must not verify as unbound
    let (cfg, _, wits, pd) = setup(2, 0xd162);
    let tk = TraceKey::setup(cfg, 2);
    let mut rng = Rng::seed_from_u64(42);
    let mut proof = prove_trace_provenance(&tk, &wits, &pd, &mut rng).expect("rows open");
    proof.provenance = None;
    assert!(
        verify_trace(&tk, &proof).is_err(),
        "stripping the provenance payload must not yield a valid plain trace"
    );
}

#[test]
fn grafted_provenance_payload_is_rejected() {
    // a provenance payload transplanted onto a plain trace (same config,
    // same step count) lands in a different transcript and fails
    let (cfg, _, wits, pd) = setup(2, 0xd163);
    let tk = TraceKey::setup(cfg, 2);
    let mut rng = Rng::seed_from_u64(43);
    let donor = prove_trace_provenance(&tk, &wits, &pd, &mut rng).expect("rows open");
    let (_, _, wits2, _) = setup(2, 0xd164);
    let mut plain = prove_trace(&tk, &wits2, &mut rng);
    verify_trace(&tk, &plain).expect("plain trace verifies");
    plain.provenance = donor.provenance;
    assert!(
        verify_trace(&tk, &plain).is_err(),
        "grafting a provenance payload onto another trace must fail"
    );
}

#[test]
fn tampered_provenance_statement_and_claims_are_rejected() {
    let (cfg, _, wits, pd) = setup(2, 0xd165);
    let tk = TraceKey::setup(cfg, 2);
    let mut rng = Rng::seed_from_u64(44);
    let proof = prove_trace_provenance(&tk, &wits, &pd, &mut rng).expect("rows open");
    verify_trace(&tk, &proof).expect("honest proof verifies");

    // swapped endorsement root (the dataset-substitution attack)
    let mut bad = proof.clone();
    bad.provenance.as_mut().unwrap().dataset.root[0] ^= 1;
    assert!(verify_trace(&tk, &bad).is_err(), "edited root must fail");

    // lying dataset opening
    let mut bad = proof.clone();
    bad.provenance.as_mut().unwrap().v_dpts += Fr::ONE;
    assert!(verify_trace(&tk, &bad).is_err(), "edited D̃ claim must fail");

    // lying label opening
    let mut bad = proof.clone();
    bad.provenance.as_mut().unwrap().v_dlab += Fr::ONE;
    assert!(verify_trace(&tk, &bad).is_err(), "edited label claim must fail");

    // lying selection evaluation
    let mut bad = proof.clone();
    bad.provenance.as_mut().unwrap().sel_evals[0] += Fr::ONE;
    assert!(verify_trace(&tk, &bad).is_err(), "edited S̃ claim must fail");

    // lying input evaluation
    let mut bad = proof.clone();
    bad.provenance.as_mut().unwrap().v_x[1] += Fr::ONE;
    assert!(verify_trace(&tk, &bad).is_err(), "edited X̃ claim must fail");

    // lying booleanity opening
    let mut bad = proof.clone();
    bad.provenance.as_mut().unwrap().v_sel += Fr::ONE;
    assert!(verify_trace(&tk, &bad).is_err(), "edited sign opening must fail");

    // shrunk dataset statement (n_rows drives the key + row-sum mask)
    let mut bad = proof.clone();
    bad.provenance.as_mut().unwrap().dataset.n_rows -= 1;
    assert!(verify_trace(&tk, &bad).is_err(), "edited row count must fail");
}

#[test]
fn provenance_tampers_carry_their_own_failure_classes() {
    // zkFlight taxonomy: a broken selection argument and a broken
    // booleanity instance must be distinguishable in the journal
    let (cfg, _, wits, pd) = setup(2, 0xd167);
    let tk = TraceKey::setup(cfg, 2);
    let mut rng = Rng::seed_from_u64(46);
    let proof = prove_trace_provenance(&tk, &wits, &pd, &mut rng).expect("rows open");
    verify_trace(&tk, &proof).expect("honest proof verifies");

    // a lying selection evaluation fails the zkData phase wholesale
    let mut bad = proof.clone();
    bad.provenance.as_mut().unwrap().sel_evals[0] += Fr::ONE;
    let err = verify_trace(&tk, &bad).expect_err("edited S̃ claim must fail");
    assert_eq!(
        failure_class(&err),
        Some(VerifyFailureClass::ProvenanceSelection),
        "wrong class: {err:#}"
    );

    // a broken booleanity IPA carries the more specific inner class —
    // attach-once means the zkData wrapper does not overwrite it
    let mut bad = proof.clone();
    bad.provenance.as_mut().unwrap().validity.ipa.l.pop();
    let err = verify_trace(&tk, &bad).expect_err("broken booleanity must fail");
    assert_eq!(
        failure_class(&err),
        Some(VerifyFailureClass::Booleanity),
        "wrong class: {err:#}"
    );
}

#[test]
fn decoder_rejects_malformed_provenance_payloads() {
    let (cfg, _, wits, pd) = setup(2, 0xd166);
    let tk = TraceKey::setup(cfg, 2);
    let mut rng = Rng::seed_from_u64(45);
    let proof = prove_trace_provenance(&tk, &wits, &pd, &mut rng).expect("rows open");

    // claim-vector length mismatch
    let mut bad = proof.clone();
    bad.provenance.as_mut().unwrap().v_x.pop();
    assert!(decode_trace_proof(&encode_trace_proof(&cfg, &bad)).is_err());

    // missing opening
    let mut bad = proof.clone();
    bad.provenance.as_mut().unwrap().openings.pop();
    assert!(decode_trace_proof(&encode_trace_proof(&cfg, &bad)).is_err());

    // missing booleanity sign commitment
    let mut bad = proof.clone();
    bad.provenance.as_mut().unwrap().p1_sel.com_sign_prime = None;
    assert!(decode_trace_proof(&encode_trace_proof(&cfg, &bad)).is_err());

    // empty dataset statement
    let mut bad = proof.clone();
    bad.provenance.as_mut().unwrap().dataset.n_rows = 0;
    assert!(decode_trace_proof(&encode_trace_proof(&cfg, &bad)).is_err());

    // absurd dataset size (decoder resource ceiling)
    let mut bad = proof;
    bad.provenance.as_mut().unwrap().dataset.n_rows = usize::MAX / 2;
    assert!(decode_trace_proof(&encode_trace_proof(&cfg, &bad)).is_err());
}
