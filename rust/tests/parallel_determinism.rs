//! zkLanes determinism guards: proof artifacts must be byte-identical at
//! every `ZKDL_THREADS` setting.
//!
//! The pool helpers only change *where* work runs, never *what* is
//! computed: disjoint-slice fills write each slot exactly once, and
//! `par_reduce` combines per-chunk partials in ascending chunk order —
//! exact modular arithmetic in `Fr` is associative and commutative, so the
//! chunked sum equals the sequential fold bit-for-bit. These tests pin that
//! contract end-to-end (wire-encoded trace proofs across 1/2/8 lanes) and
//! at the primitive level (`par_reduce` vs a sequential fold), plus the
//! one-MSM verifier invariant with the pool active.
//!
//! Every test that flips `ZKDL_THREADS` runs under the same lock so the
//! parallel test harness cannot interleave env mutations.

use std::sync::Mutex;

use zkdl::aggregate::{
    prove_trace, prove_trace_chained, prove_trace_provenance, verify_trace, TraceKey,
};
use zkdl::data::Dataset;
use zkdl::model::ModelConfig;
use zkdl::provenance::ProverDataset;
use zkdl::util::rng::Rng;
use zkdl::util::threads;
use zkdl::witness::StepWitness;
use zkdl::{telemetry, wire, Fr};

static ENV_LOCK: Mutex<()> = Mutex::new(());

/// Run `f` with `ZKDL_THREADS` pinned to `n`, restoring the prior setting.
/// The pool re-reads the variable on every dispatch, so this retargets lane
/// count mid-process without restarting workers.
fn with_threads<T>(n: usize, f: impl FnOnce() -> T) -> T {
    let saved = std::env::var("ZKDL_THREADS").ok();
    std::env::set_var("ZKDL_THREADS", n.to_string());
    let out = f();
    match saved {
        Some(v) => std::env::set_var("ZKDL_THREADS", v),
        None => std::env::remove_var("ZKDL_THREADS"),
    }
    out
}

struct Fixture {
    tk: TraceKey,
    wits: Vec<StepWitness>,
    pd: ProverDataset,
}

fn fixture() -> Fixture {
    // T=2 so the chained (zkOptim) variant is provable; small shape keeps
    // the 9 prove calls (3 variants x 3 thread counts) cheap in debug.
    let cfg = ModelConfig::new(2, 8, 4);
    let ds = Dataset::synthetic(16, 4, 4, cfg.r_bits, 5);
    let wits = sgd(cfg, &ds, 2, 7);
    let tk = TraceKey::setup(cfg, 2);
    let pd = ProverDataset::build(&ds, &tk.cfg).expect("dataset commits");
    Fixture { tk, wits, pd }
}

fn sgd(cfg: ModelConfig, ds: &Dataset, t: usize, seed: u64) -> Vec<StepWitness> {
    zkdl::witness::native::sgd_witness_chain(cfg, ds, t, seed)
}

/// Wire-encoded (plain, chained, provenance) trace proofs, each produced
/// from an identically seeded rng — blinds are drawn sequentially on the
/// caller thread, so the draw sequence is lane-count-independent.
fn artifacts(fx: &Fixture, lanes: usize) -> [Vec<u8>; 3] {
    with_threads(lanes, || {
        let mut rng = Rng::seed_from_u64(0xD15C);
        let plain = prove_trace(&fx.tk, &fx.wits, &mut rng);
        let mut rng = Rng::seed_from_u64(0xD15C);
        let chained =
            prove_trace_chained(&fx.tk, &fx.wits, &mut rng).expect("witnesses chain");
        let mut rng = Rng::seed_from_u64(0xD15C);
        let prov = prove_trace_provenance(&fx.tk, &fx.wits, &fx.pd, &mut rng)
            .expect("rows open against dataset");
        verify_trace(&fx.tk, &plain).expect("plain verifies");
        [
            wire::encode_trace_proof(&fx.tk.cfg, &plain),
            wire::encode_trace_proof(&fx.tk.cfg, &chained),
            wire::encode_trace_proof(&fx.tk.cfg, &prov),
        ]
    })
}

#[test]
fn trace_artifacts_are_byte_identical_across_thread_counts() {
    let _guard = ENV_LOCK.lock().unwrap();
    let fx = fixture();
    let base = artifacts(&fx, 1);
    assert!(base.iter().all(|a| !a.is_empty()));
    for lanes in [2usize, 8] {
        let got = artifacts(&fx, lanes);
        for (variant, (a, b)) in ["plain", "chained", "provenance"]
            .iter()
            .zip(base.iter().zip(got.iter()))
        {
            assert_eq!(
                a, b,
                "{variant} artifact diverged between 1 and {lanes} threads"
            );
        }
    }
}

#[test]
fn par_reduce_matches_sequential_fold_at_every_lane_count() {
    let _guard = ENV_LOCK.lock().unwrap();
    let mut rng = Rng::seed_from_u64(0xFA57);
    let values: Vec<Fr> = (0..4097)
        .map(|_| Fr::from_i64(rng.gen_i64(-(1i64 << 40), 1i64 << 40)))
        .collect();
    let seq = values.iter().fold(Fr::ZERO, |acc, v| acc + *v);
    // Lane count drives the chunk boundaries, so sweeping it exercises many
    // different splits of the same reduction (including uneven tails).
    for lanes in [1usize, 2, 3, 5, 8, 13] {
        let par = with_threads(lanes, || {
            threads::par_reduce(
                values.len(),
                1,
                Fr::ZERO,
                |range, mut acc| {
                    for i in range {
                        acc += values[i];
                    }
                    acc
                },
                |a, b| a + b,
            )
        });
        assert_eq!(seq, par, "par_reduce diverged at {lanes} lanes");
    }
}

#[test]
fn one_msm_flush_invariant_holds_with_pool_active() {
    let _guard = ENV_LOCK.lock().unwrap();
    let fx = fixture();
    with_threads(8, || {
        let mut rng = Rng::seed_from_u64(3);
        let proof = prove_trace(&fx.tk, &fx.wits, &mut rng);
        let ((), rep) = telemetry::capture(|| {
            verify_trace(&fx.tk, &proof).expect("trace verifies");
        });
        let get = |name: &str| -> u64 {
            rep.counters
                .iter()
                .find(|(n, _)| *n == name)
                .map(|(_, v)| *v)
                .unwrap_or(0)
        };
        assert_eq!(get("msm/flushes"), 1, "one deferred MSM per verification");
        assert_eq!(
            get("msm/calls"),
            get("msm/flushes"),
            "verification must not run MSMs outside the accumulator flush"
        );
    });
}

#[test]
fn pool_dispatch_counters_tick_during_parallel_prove() {
    let _guard = ENV_LOCK.lock().unwrap();
    let fx = fixture();
    with_threads(8, || {
        let ((), rep) = telemetry::capture(|| {
            let mut rng = Rng::seed_from_u64(4);
            let proof = prove_trace(&fx.tk, &fx.wits, &mut rng);
            std::hint::black_box(&proof);
        });
        let get = |name: &str| -> u64 {
            rep.counters
                .iter()
                .find(|(n, _)| *n == name)
                .map(|(_, v)| *v)
                .unwrap_or(0)
        };
        // Every dispatched job lands in exactly one of the two counters
        // (queued, or run inline on queue saturation).
        assert!(
            get("pool/jobs") + get("pool/queue_full") > 0,
            "an 8-lane prove must dispatch at least one pooled job"
        );
    });
}
