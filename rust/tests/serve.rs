//! zkServe loopback integration tests: a real daemon on an ephemeral port,
//! real `TcpStream` clients, and counter-proven MSM coalescing.
//!
//! Every test that spawns a [`Server`] runs under
//! [`telemetry::exclusive`] — counters are process-global, so two daemons
//! measuring concurrently would double-count each other's flushes.

use std::io::Write as _;
use std::net::TcpStream;
use std::time::Duration;

use zkdl::aggregate::{prove_trace, prove_trace_provenance, TraceKey};
use zkdl::data::Dataset;
use zkdl::model::ModelConfig;
use zkdl::provenance::ProverDataset;
use zkdl::serve::protocol::{self, read_frame, Frame, ReadOutcome};
use zkdl::serve::{status, submit, ServeConfig, Server};
use zkdl::telemetry::failure::VerifyFailureClass;
use zkdl::telemetry::json::Json;
use zkdl::telemetry::{self, Counter};
use zkdl::util::rng::Rng;
use zkdl::witness::native::sgd_witness_chain;

fn cfg() -> ModelConfig {
    ModelConfig::new(2, 8, 4)
}

/// One T=1 trace artifact in the wire encoding (no provenance → the `None`
/// shard). Distinct seeds give distinct proofs of the same shape.
fn plain_artifact(seed: u64) -> Vec<u8> {
    let cfg = cfg();
    let ds = Dataset::synthetic(32, cfg.width / 2, 4, cfg.r_bits, seed ^ 0x77);
    let wits = sgd_witness_chain(cfg, &ds, 1, seed);
    let tk = TraceKey::setup(cfg, 1);
    let mut rng = Rng::seed_from_u64(seed);
    zkdl::wire::encode_trace_proof(&cfg, &prove_trace(&tk, &wits, &mut rng))
}

/// A provenance-bound artifact; the dataset seed decides its root shard.
fn provenance_artifact(seed: u64) -> Vec<u8> {
    let cfg = cfg();
    let ds = Dataset::synthetic(32, cfg.width / 2, 4, cfg.r_bits, seed ^ 0x77);
    let wits = sgd_witness_chain(cfg, &ds, 1, seed);
    let tk = TraceKey::setup(cfg, 1);
    let pd = ProverDataset::build(&ds, &cfg).expect("dataset commits");
    let mut rng = Rng::seed_from_u64(seed);
    let proof = prove_trace_provenance(&tk, &wits, &pd, &mut rng).expect("provenance proof");
    zkdl::wire::encode_trace_proof(&cfg, &proof)
}

/// Decode-clean but verify-rejected: a tampered scalar claim.
fn tampered_artifact(seed: u64) -> Vec<u8> {
    let cfg = cfg();
    let ds = Dataset::synthetic(32, cfg.width / 2, 4, cfg.r_bits, seed ^ 0x77);
    let wits = sgd_witness_chain(cfg, &ds, 1, seed);
    let tk = TraceKey::setup(cfg, 1);
    let mut rng = Rng::seed_from_u64(seed);
    let mut proof = prove_trace(&tk, &wits, &mut rng);
    proof.v_z[0] = proof.v_z[0] + zkdl::Fr::ONE;
    zkdl::wire::encode_trace_proof(&cfg, &proof)
}

fn spawn(max_batch: usize, max_wait: Duration, queue_cap: usize) -> Server {
    Server::spawn(ServeConfig {
        addr: "127.0.0.1:0".into(),
        max_batch,
        max_wait,
        queue_cap,
        poll_interval: Duration::from_millis(50),
        write_timeout: Duration::from_secs(10),
        journal: None,
    })
    .expect("daemon binds loopback")
}

const CLIENT_TIMEOUT: Duration = Duration::from_secs(120);

#[test]
fn coalesces_concurrent_submissions_into_one_msm() {
    const N: usize = 4;
    let artifact = plain_artifact(1);
    telemetry::exclusive(|| {
        telemetry::reset();
        telemetry::set_enabled(true);
        // max_batch = N and a long max_wait: the shard can only flush once
        // every client has been admitted — the tick is deterministic
        let server = spawn(N, Duration::from_secs(60), 64);
        let addr = server.addr().to_string();
        let handles: Vec<_> = (0..N)
            .map(|_| {
                let addr = addr.clone();
                let artifact = artifact.clone();
                std::thread::spawn(move || submit(&addr, &artifact, CLIENT_TIMEOUT))
            })
            .collect();
        for h in handles {
            let frame = h.join().expect("client thread").expect("verdict");
            assert_eq!(frame, Frame::Accepted);
        }
        assert_eq!(
            telemetry::counter_value(Counter::MsmFlushes),
            1,
            "N concurrent submissions must coalesce into ONE MSM"
        );
        assert_eq!(telemetry::counter_value(Counter::ServeBatches), 1);
        assert_eq!(
            telemetry::counter_value(Counter::ServeCoalesced),
            (N - 1) as u64
        );
        assert_eq!(telemetry::counter_value(Counter::ServeFrames), N as u64);
        let stats = server.shutdown();
        assert_eq!(stats.batches, 1);
        assert_eq!(stats.frames, N as u64);
        telemetry::set_enabled(false);
        telemetry::reset();
    });
}

#[test]
fn shards_by_dataset_root() {
    let a = provenance_artifact(11);
    let b = provenance_artifact(22);
    telemetry::exclusive(|| {
        telemetry::reset();
        telemetry::set_enabled(true);
        // two roots × two copies each, max_batch=2: each root shard flushes
        // exactly when its pair is complete — two batches, two MSMs
        let server = spawn(2, Duration::from_secs(60), 64);
        let addr = server.addr().to_string();
        let handles: Vec<_> = [a.clone(), a, b.clone(), b]
            .into_iter()
            .map(|artifact| {
                let addr = addr.clone();
                std::thread::spawn(move || submit(&addr, &artifact, CLIENT_TIMEOUT))
            })
            .collect();
        for h in handles {
            let frame = h.join().expect("client thread").expect("verdict");
            assert_eq!(frame, Frame::Accepted);
        }
        assert_eq!(
            telemetry::counter_value(Counter::MsmFlushes),
            2,
            "one MSM per root shard"
        );
        assert_eq!(telemetry::counter_value(Counter::ServeBatches), 2);
        assert_eq!(telemetry::counter_value(Counter::ServeCoalesced), 2);
        server.shutdown();
        telemetry::set_enabled(false);
        telemetry::reset();
    });
}

#[test]
fn tampered_artifact_is_attributed_within_batch() {
    let good = plain_artifact(5);
    let bad = tampered_artifact(6);
    telemetry::exclusive(|| {
        // one tampered artifact rides a batch of three: the batch MSM
        // rejects, per-proof re-attribution blames exactly the tampered one
        let server = spawn(3, Duration::from_secs(60), 64);
        let addr = server.addr().to_string();
        let handles: Vec<_> = [(good.clone(), true), (good, true), (bad, false)]
            .into_iter()
            .map(|(artifact, want_ok)| {
                let addr = addr.clone();
                std::thread::spawn(move || (submit(&addr, &artifact, CLIENT_TIMEOUT), want_ok))
            })
            .collect();
        for h in handles {
            let (result, want_ok) = h.join().expect("client thread");
            let frame = result.expect("verdict");
            if want_ok {
                assert_eq!(frame, Frame::Accepted);
            } else {
                match frame {
                    Frame::Rejected { class, message } => {
                        assert!(class.is_some(), "typed class expected, got: {message}");
                    }
                    other => panic!("tampered artifact accepted: {other:?}"),
                }
            }
        }
        server.shutdown();
    });
}

#[test]
fn survives_garbage_and_oversized_frames() {
    let artifact = plain_artifact(9);
    telemetry::exclusive(|| {
        let server = spawn(1, Duration::from_millis(20), 64);
        let addr = server.addr();

        // garbage where a frame header should be
        let mut s = TcpStream::connect(addr).expect("connect");
        s.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
        s.write_all(b"GARBAGE!GARBAGE!").expect("write garbage");
        match read_frame(&mut s).expect("framing-error response") {
            ReadOutcome::Frame(Frame::Rejected { class, .. }) => {
                assert_eq!(class.as_deref(), Some(VerifyFailureClass::WireDecode.name()));
            }
            _ => panic!("expected a rejection frame"),
        }

        // a valid header claiming a multi-gigabyte payload: refused before
        // any allocation, connection dropped
        let mut s = TcpStream::connect(addr).expect("connect");
        s.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
        let mut header = Vec::new();
        header.extend_from_slice(&protocol::FRAME_MAGIC);
        header.extend_from_slice(&protocol::PROTOCOL_VERSION.to_le_bytes());
        header.extend_from_slice(&1u16.to_le_bytes()); // submit
        header.extend_from_slice(&u32::MAX.to_le_bytes());
        s.write_all(&header).expect("write oversized header");
        match read_frame(&mut s).expect("oversize response") {
            ReadOutcome::Frame(Frame::Rejected { message, .. }) => {
                assert!(message.contains("exceeds"), "got: {message}");
            }
            _ => panic!("expected a rejection frame"),
        }

        // a raw artifact piped at the socket (wrong magic) is a framing error
        let mut s = TcpStream::connect(addr).expect("connect");
        s.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
        s.write_all(&artifact).expect("write raw artifact");
        match read_frame(&mut s).expect("artifact-at-socket response") {
            ReadOutcome::Frame(Frame::Rejected { .. }) => {}
            _ => panic!("expected a rejection frame"),
        }

        // after all that abuse the daemon still verifies valid traffic
        let frame = submit(&addr.to_string(), &artifact, CLIENT_TIMEOUT).expect("verdict");
        assert_eq!(frame, Frame::Accepted);
        server.shutdown();
    });
}

fn wait_for_queue_len(addr: &str, want: u64) {
    let deadline = std::time::Instant::now() + Duration::from_secs(30);
    loop {
        let json = status(addr, Duration::from_secs(5)).expect("status");
        let doc = Json::parse(&json).expect("status JSON parses");
        let got = doc.get("queue_len").and_then(|v| v.as_u64()).unwrap_or(0);
        if got >= want {
            return;
        }
        assert!(
            std::time::Instant::now() < deadline,
            "queue never reached {want}"
        );
        std::thread::sleep(Duration::from_millis(10));
    }
}

#[test]
fn overload_backpressure_and_drain_under_shutdown() {
    let artifact = plain_artifact(13);
    telemetry::exclusive(|| {
        telemetry::reset();
        telemetry::set_enabled(true);
        // queue_cap=1 and a shard that never fills: the first submission
        // parks, the second bounces off the admission bound
        let server = spawn(64, Duration::from_secs(60), 1);
        let addr = server.addr().to_string();
        let first = {
            let addr = addr.clone();
            let artifact = artifact.clone();
            std::thread::spawn(move || submit(&addr, &artifact, CLIENT_TIMEOUT))
        };
        wait_for_queue_len(&addr, 1);
        match submit(&addr, &artifact, CLIENT_TIMEOUT).expect("second verdict") {
            Frame::Overloaded => {}
            other => panic!("expected overload backpressure, got {other:?}"),
        }
        assert_eq!(telemetry::counter_value(Counter::ServeOverload), 1);
        // graceful shutdown drains the parked submission to its REAL
        // verdict — not a refusal
        let stats = server.shutdown();
        let frame = first.join().expect("client thread").expect("verdict");
        assert_eq!(frame, Frame::Accepted, "drain must deliver the verdict");
        assert_eq!(stats.overloads, 1);
        telemetry::set_enabled(false);
        telemetry::reset();
    });
}

#[test]
fn status_reports_schema_counters_and_hists() {
    telemetry::exclusive(|| {
        let server = spawn(4, Duration::from_millis(20), 8);
        let json = status(&server.addr().to_string(), Duration::from_secs(10)).expect("status");
        let doc = Json::parse(&json).expect("status JSON parses");
        assert_eq!(
            doc.get("schema").and_then(|v| v.as_str()),
            Some(zkdl::serve::STATUS_SCHEMA)
        );
        let counters = doc.get("counters").expect("counters block");
        for key in ["serve/frames", "serve/batches", "serve/overload", "msm/flushes"] {
            assert!(counters.get(key).is_some(), "missing counter {key}");
        }
        let hists = doc.get("hists").expect("hists block");
        assert!(hists.get("lat/serve_submit_ns").is_some());
        assert!(hists.get("serve/batch_size").is_some());
        server.shutdown();
    });
}

#[test]
fn journals_every_decision() {
    let artifact = plain_artifact(21);
    let path = std::env::temp_dir().join(format!(
        "zkdl-serve-journal-{}.jsonl",
        std::process::id()
    ));
    let _ = std::fs::remove_file(&path);
    telemetry::exclusive(|| {
        telemetry::reset();
        telemetry::set_enabled(true);
        let server = Server::spawn(ServeConfig {
            addr: "127.0.0.1:0".into(),
            max_batch: 1,
            max_wait: Duration::from_millis(10),
            queue_cap: 8,
            poll_interval: Duration::from_millis(50),
            write_timeout: Duration::from_secs(10),
            journal: Some(path.clone()),
        })
        .expect("daemon binds loopback");
        let addr = server.addr().to_string();
        let frame = submit(&addr, &artifact, CLIENT_TIMEOUT).expect("verdict");
        assert_eq!(frame, Frame::Accepted);
        // one framing failure: journaled before the response is written, so
        // reading the reply synchronizes with the journal append
        let mut s = TcpStream::connect(server.addr()).expect("connect");
        s.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
        s.write_all(&[0u8; 12]).expect("write zeros");
        let _ = read_frame(&mut s);
        server.shutdown();
        telemetry::set_enabled(false);
        telemetry::reset();
    });
    let (events, bad) = zkdl::telemetry::journal::read_journal(&path).expect("journal reads");
    assert_eq!(bad, 0, "no malformed journal lines");
    assert!(
        events.iter().any(|e| e.verb == "serve-verify"
            && e.outcome == "accepted"
            && e.batch_size == Some(1)
            && e.artifact_sha256.is_some()),
        "accepted submission journaled: {events:?}"
    );
    assert!(
        events.iter().any(|e| e.verb == "serve-frame"
            && e.outcome == "rejected"
            && e.failure_class.as_deref() == Some(VerifyFailureClass::WireDecode.name())),
        "framing rejection journaled with class: {events:?}"
    );
    let _ = std::fs::remove_file(&path);
}
