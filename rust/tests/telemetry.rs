//! zkObs integration tests: disabled-mode overhead guards (no allocations,
//! bounded time), cross-thread span merging, counter accuracy against the
//! accumulator's one-MSM invariant, and the BENCH_*.json golden schema.
//!
//! This binary installs a counting `#[global_allocator]`, so the overhead
//! tests live here rather than in the unit-test binary. The counter is
//! per-thread (a `const`-init TLS cell — itself allocation-free), so the
//! guards stay exact even when the harness runs other tests in parallel
//! threads.

use std::alloc::{GlobalAlloc, Layout, System};
use std::cell::Cell;
use std::time::Instant;

use zkdl::aggregate::{prove_trace, verify_trace, TraceKey};
use zkdl::data::Dataset;
use zkdl::model::ModelConfig;
use zkdl::telemetry::bench::{run_grid, GridOptions, BENCH_SCHEMA};
use zkdl::telemetry::journal::{read_journal, Journal, JournalEvent};
use zkdl::telemetry::json::Json;
use zkdl::telemetry::{self, trace_export, Counter};
use zkdl::util::rng::Rng;
use zkdl::witness::native::sgd_witness_chain;

struct CountingAlloc;

thread_local! {
    static LOCAL_ALLOCS: Cell<u64> = const { Cell::new(0) };
}

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        // try_with: TLS may be mid-teardown; the counter is best-effort there
        let _ = LOCAL_ALLOCS.try_with(|c| c.set(c.get() + 1));
        System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static ALLOC: CountingAlloc = CountingAlloc;

fn thread_allocs() -> u64 {
    LOCAL_ALLOCS.with(|c| c.get())
}

/// ~100 ns of real work per iteration, so the disabled instrumentation
/// (two relaxed loads) is a small fraction of the loop body.
#[inline(never)]
fn work(i: u64) -> u64 {
    let mut acc = i;
    for _ in 0..64 {
        acc = acc.wrapping_mul(6364136223846793005).wrapping_add(1);
    }
    std::hint::black_box(acc)
}

#[inline(never)]
fn baseline_loop(n: u64) -> u64 {
    let mut acc = 0u64;
    for i in 0..n {
        acc ^= work(i);
    }
    acc
}

#[inline(never)]
fn instrumented_loop(n: u64) -> u64 {
    let mut acc = 0u64;
    for i in 0..n {
        zkdl::span!("test/hot_loop");
        telemetry::count(Counter::MsmCalls, 1);
        acc ^= work(i);
    }
    acc
}

#[test]
fn disabled_instrumentation_does_not_allocate() {
    telemetry::exclusive(|| {
        assert!(!telemetry::enabled(), "telemetry must be off by default");
        // warm up (the first TLS touch may allocate lazily), and spin up the
        // zkLanes pool — its workers idling must not charge this thread
        let _ = zkdl::util::threads::par_map_with(0, (0..32u64).collect(), |x| x + 1);
        std::hint::black_box(instrumented_loop(10));
        let before = thread_allocs();
        std::hint::black_box(instrumented_loop(50_000));
        assert_eq!(
            thread_allocs() - before,
            0,
            "disabled span!/count must not allocate"
        );
    });
}

#[test]
fn disabled_instrumentation_overhead_is_bounded() {
    // Debug builds don't inline the relaxed-load fast path, so the 5%
    // release-mode guard gets slack there; CI's release smoke run holds
    // the real bound.
    let tolerance = if cfg!(debug_assertions) { 1.60 } else { 1.05 };
    let n = 50_000u64;
    telemetry::exclusive(|| {
        assert!(!telemetry::enabled());
        // warm up both paths
        std::hint::black_box(baseline_loop(n / 10));
        std::hint::black_box(instrumented_loop(n / 10));
        // min-of-k over several attempts: scheduling noise inflates single
        // samples, never deflates them
        let mut ok = false;
        for _ in 0..5 {
            let mut base = f64::INFINITY;
            let mut inst = f64::INFINITY;
            for _ in 0..3 {
                let t = Instant::now();
                std::hint::black_box(baseline_loop(n));
                base = base.min(t.elapsed().as_secs_f64());
                let t = Instant::now();
                std::hint::black_box(instrumented_loop(n));
                inst = inst.min(t.elapsed().as_secs_f64());
            }
            if inst <= base * tolerance {
                ok = true;
                break;
            }
        }
        assert!(ok, "disabled instrumentation exceeded {tolerance}x overhead");
    });
}

#[test]
fn sumcheck_prover_inner_loop_is_allocation_free() {
    use zkdl::poly::Mle;
    use zkdl::sumcheck::{prove, Instance, Term};
    use zkdl::transcript::Transcript;
    use zkdl::Fr;

    // `exclusive` serializes this with the bench-grid test, which also
    // mutates ZKDL_THREADS; one lane keeps all prover work on this thread
    // so the per-thread allocation counter sees every allocation.
    telemetry::exclusive(|| {
        let saved = std::env::var("ZKDL_THREADS").ok();
        std::env::set_var("ZKDL_THREADS", "1");

        let num_vars = 12usize;
        let n = 1usize << num_vars;
        let mk = |mult: i64| {
            Mle::new(
                (0..n)
                    .map(|i| Fr::from_i64((i as i64).wrapping_mul(mult) - 7))
                    .collect(),
            )
        };
        // A two-term instance with a degree-3 product — the deepest shape
        // zkDL produces (eq·(1−B)·Z).
        let inst = Instance::new(vec![
            Term::new(Fr::from_i64(3), vec![mk(3), mk(5), mk(11)]),
            Term::new(Fr::from_i64(-2), vec![mk(7), mk(13)]),
        ]);
        let mut transcript = Transcript::new(b"zkdl/test/alloc");
        let before = thread_allocs();
        let out = prove(inst, &mut transcript);
        let allocs = thread_allocs() - before;
        std::hint::black_box(&out);
        match saved {
            Some(v) => std::env::set_var("ZKDL_THREADS", v),
            None => std::env::remove_var("ZKDL_THREADS"),
        }
        // Per-round bookkeeping (the evals Vec, transcript absorbs,
        // challenge hashing) is O(num_vars) total. A single allocation per
        // hypercube index — e.g. the pre-zkLanes per-index `lines` Vec —
        // would alone cost Σ_rounds half = 2^num_vars = 4096 here.
        assert!(
            allocs < 1024,
            "sumcheck prove allocated {allocs} times for num_vars={num_vars}; \
             the inner loop must be allocation-free"
        );
    });
}

#[test]
fn spans_merge_from_exited_threads() {
    let ((), rep) = telemetry::capture(|| {
        let handles: Vec<_> = (0..3)
            .map(|_| {
                std::thread::spawn(|| {
                    zkdl::telemetry::timed("test/spawned_worker", || {
                        std::hint::black_box(work(17));
                    });
                })
            })
            .collect();
        for h in handles {
            h.join().expect("worker thread");
        }
    });
    let node = rep
        .spans
        .find("test/spawned_worker")
        .expect("spawned threads' spans merged at exit");
    assert_eq!(node.calls, 3);
}

#[test]
fn verify_trace_msm_count_matches_flush_invariant() {
    // Everything up to verification runs unprofiled; the capture window
    // holds exactly one verify_trace call, whose only curve::msm invocation
    // must be the accumulator's single deferred flush.
    let cfg = ModelConfig::new(2, 8, 4);
    let ds = Dataset::synthetic(16, 4, 4, cfg.r_bits, 5);
    let wits = sgd_witness_chain(cfg, &ds, 2, 7);
    let tk = TraceKey::setup(cfg, 2);
    let mut rng = Rng::seed_from_u64(1);
    let proof = prove_trace(&tk, &wits, &mut rng);

    let ((), rep) = telemetry::capture(|| {
        verify_trace(&tk, &proof).expect("trace verifies");
    });
    let get = |name: &str| -> u64 {
        rep.counters
            .iter()
            .find(|(n, _)| *n == name)
            .unwrap_or_else(|| panic!("counter {name} missing"))
            .1
    };
    assert_eq!(get("msm/flushes"), 1, "one deferred MSM per verification");
    assert_eq!(
        get("msm/calls"),
        get("msm/flushes"),
        "verification must not run MSMs outside the accumulator flush"
    );
    assert!(get("msm/points") > 0);
    assert!(get("msm/equations") > 0);
    assert!(get("sumcheck/verify_rounds") > 0);
    assert!(get("transcript/absorbs") > 0);
    assert!(get("transcript/challenges") > 0);
    assert!(rep.spans.find("aggregate/verify_trace").is_some());
}

// ---------------------------------------------------------------------------
// zkFlight: histograms, journal, Perfetto export
// ---------------------------------------------------------------------------

#[test]
fn verify_trace_latency_and_msm_sizes_land_in_histograms() {
    let cfg = ModelConfig::new(2, 8, 4);
    let ds = Dataset::synthetic(16, 4, 4, cfg.r_bits, 6);
    let wits = sgd_witness_chain(cfg, &ds, 2, 8);
    let tk = TraceKey::setup(cfg, 2);
    let mut rng = Rng::seed_from_u64(2);
    let proof = prove_trace(&tk, &wits, &mut rng);

    let ((), rep) = telemetry::capture(|| {
        verify_trace(&tk, &proof).expect("trace verifies");
    });
    let get = |name: &str| rep.hists.iter().find(|(n, _)| *n == name).map(|(_, s)| s);
    let lat = get("lat/verify_trace_ns").expect("verify latency histogram recorded");
    assert_eq!(lat.count, 1);
    assert!(lat.p50 > 0 && lat.p50 <= lat.max);
    assert!(lat.p95 >= lat.p50 && lat.p99 >= lat.p95);
    // exactly one MSM per verification (the deferred flush), so exactly one
    // size sample — this doubles as a histogram-side one-MSM guard
    let msm = get("msm/size").expect("msm size histogram recorded");
    assert_eq!(msm.count, 1, "one MSM size sample per verification");
    assert!(msm.p50 > 0);
    // proving ran before the capture window: no prove-side samples
    assert!(get("lat/prove_trace_ns").is_none());

    // the rendered profile and the JSON export both carry the rows
    let text = rep.render();
    assert!(text.contains("-- histograms --"), "{text}");
    assert!(text.contains("lat/verify_trace_ns"), "{text}");
    let json = Json::parse(&rep.to_json().to_string()).expect("report JSON parses");
    let hists = json.get("hists").expect("hists key in report JSON");
    let p50 = hists
        .get("lat/verify_trace_ns")
        .and_then(|h| h.get("p50"))
        .and_then(|v| v.as_u64())
        .expect("p50 row");
    assert!(p50 > 0);
}

#[test]
fn journal_seq_survives_reopen_and_reads_back() {
    use std::io::Write as _;
    let path = std::env::temp_dir().join(format!("zkdl_flight_{}.jsonl", std::process::id()));
    let _ = std::fs::remove_file(&path);
    {
        let mut j = Journal::open(&path).expect("opens fresh");
        j.append(JournalEvent::new("prove-trace", "proved")).expect("appends");
        j.append(JournalEvent::new("verify-trace", "accepted")).expect("appends");
    }
    {
        // a second process opening the same journal must continue, not rewind
        let mut j = Journal::open(&path).expect("reopens");
        let mut ev = JournalEvent::new("verify-trace", "rejected");
        ev.failure_class = Some("sumcheck".into());
        j.append(ev).expect("appends");
    }
    let (events, bad) = read_journal(&path).expect("reads back");
    assert_eq!(bad, 0);
    let seqs: Vec<u64> = events.iter().map(|e| e.seq).collect();
    assert_eq!(seqs, vec![0, 1, 2], "seq continues across reopens");
    assert_eq!(events[2].failure_class.as_deref(), Some("sumcheck"));
    // malformed lines are counted, never fatal
    std::fs::OpenOptions::new()
        .append(true)
        .open(&path)
        .unwrap()
        .write_all(b"not json\n")
        .unwrap();
    let (events, bad) = read_journal(&path).expect("still reads");
    assert_eq!((events.len(), bad), (3, 1));
    let _ = std::fs::remove_file(&path);
}

#[test]
fn trace_export_emits_balanced_chrome_events_around_real_work() {
    telemetry::exclusive(|| {
        telemetry::reset();
        telemetry::set_enabled(true);
        trace_export::set_recording(true);
        trace_export::set_thread_name("flight-test");
        {
            zkdl::span!("test/flight_outer");
            {
                zkdl::span!("test/flight_inner");
                std::hint::black_box(work(3));
            }
        }
        trace_export::set_recording(false);
        telemetry::set_enabled(false);
        let parsed = Json::parse(&trace_export::export_json().to_string())
            .expect("chrome trace-event JSON parses");
        assert_eq!(
            parsed.get("displayTimeUnit").and_then(|v| v.as_str()),
            Some("ms")
        );
        let events = parsed
            .get("traceEvents")
            .and_then(|v| v.as_array())
            .expect("traceEvents array");
        let ph = |e: &Json| e.get("ph").and_then(|v| v.as_str()).unwrap().to_string();
        // filter to this test's spans (another test's spans could land in
        // the window if it raced the enable — names are the contract)
        let ours = |e: &Json| {
            e.get("name")
                .and_then(|v| v.as_str())
                .is_some_and(|n| n.starts_with("test/flight_"))
        };
        let begins: Vec<f64> = events
            .iter()
            .filter(|e| ph(e) == "B" && ours(e))
            .map(|e| e.get("ts").and_then(|v| v.as_f64()).unwrap())
            .collect();
        let ends = events.iter().filter(|e| ph(e) == "E" && ours(e)).count();
        assert_eq!(begins.len(), 2, "outer + inner");
        assert_eq!(begins.len(), ends, "balanced B/E");
        assert!(begins[0] <= begins[1], "outer opens before inner");
        // our track is labeled
        let named = events.iter().any(|e| {
            ph(e) == "M"
                && e.get("args")
                    .and_then(|a| a.get("name"))
                    .and_then(|v| v.as_str())
                    == Some("flight-test")
        });
        assert!(named, "thread_name metadata present");
    });
}

#[test]
fn bench_quick_grid_emits_golden_schema() {
    let mut opts = GridOptions::quick();
    opts.data_rows = 32; // keep the provenance cell cheap in debug builds
    let report = run_grid(&opts);
    let text = report.render_table();
    assert!(text.contains("plain"));
    assert!(text.contains("provenance"));

    let parsed = Json::parse(&report.to_json_string()).expect("bench JSON parses");
    assert_eq!(
        parsed.get("schema").and_then(|v| v.as_str()),
        Some(BENCH_SCHEMA)
    );
    for key in ["created_unix", "threads", "config", "grid", "wall_s", "cases"] {
        assert!(parsed.get(key).is_some(), "missing {key}");
    }
    let grid = parsed.get("grid").unwrap();
    assert_eq!(grid.get("steps").unwrap().as_array().unwrap().len(), 1);
    let variants = grid.get("variants").unwrap().as_array().unwrap();
    assert_eq!(variants.len(), 3);
    // v2: the thread axis is part of the grid block (quick default: [0] = auto)
    let axis = grid.get("threads").unwrap().as_array().unwrap();
    assert_eq!(axis.len(), 1);
    assert_eq!(axis[0].as_u64(), Some(0));

    let cases = parsed.get("cases").unwrap().as_array().unwrap();
    assert_eq!(cases.len(), 3, "one case per variant at T=1, depth=2");
    for case in cases {
        for key in [
            "variant",
            "steps",
            "depth",
            "threads",
            "skipped",
            "prove_s",
            "verify_s",
            "proof_bytes",
            "msm",
        ] {
            assert!(case.get(key).is_some(), "case missing {key}");
        }
        assert_eq!(case.get("threads").and_then(|v| v.as_u64()), Some(0));
    }
    let by_variant = |name: &str| {
        cases
            .iter()
            .find(|c| c.get("variant").and_then(|v| v.as_str()) == Some(name))
            .unwrap_or_else(|| panic!("no {name} case"))
    };
    // chained cannot run at T=1 and must say so
    assert!(by_variant("chained").get("skipped").unwrap().as_str().is_some());
    // plain and provenance ran: timings, sizes, and the one-MSM invariant
    for name in ["plain", "provenance"] {
        let case = by_variant(name);
        assert_eq!(case.get("skipped"), Some(&Json::Null));
        assert!(case.get("prove_s").unwrap().as_f64().unwrap() > 0.0);
        assert!(case.get("verify_s").unwrap().as_f64().unwrap() > 0.0);
        assert!(case.get("proof_bytes").unwrap().as_u64().unwrap() > 0);
        let msm = case.get("msm").expect("msm block");
        let calls = msm.get("verify_calls").unwrap().as_u64().unwrap();
        let flushes = msm.get("verify_flushes").unwrap().as_u64().unwrap();
        assert_eq!(calls, 1, "{name}: one MSM per verification");
        assert_eq!(calls, flushes, "{name}: verify MSMs == flushes");
        assert!(msm.get("prove_calls").unwrap().as_u64().unwrap() > 0);
        assert!(msm.get("prove_points").unwrap().as_u64().unwrap() > 0);
        assert!(msm.get("verify_points").unwrap().as_u64().unwrap() > 0);
    }
}
