//! Integration tests for the FAC4DNN multi-step aggregation subsystem:
//! honest roundtrips across trace shapes, the O(T)-vs-aggregated proof-size
//! separation, and adversarial cases mirroring the per-step negative tests
//! in `integration.rs` — a tampered step witness *inside* a trace must make
//! `verify_trace` fail.

use zkdl::aggregate::{
    prove_trace, prove_trace_chained, prove_trace_chained_with, trace_stack_dims, verify_trace,
    verify_traces_batch, TraceKey, TraceProof,
};
use zkdl::curve::G1;
use zkdl::data::Dataset;
use zkdl::model::ModelConfig;
use zkdl::update::{LrSchedule, UpdateRule};
use zkdl::util::rng::Rng;
use zkdl::telemetry::failure::{failure_class, VerifyFailureClass};
use zkdl::witness::native::{rule_witness_chain, sgd_witness_chain};
use zkdl::witness::StepWitness;
use zkdl::zkdl::{prove_step, verify_step, ProofMode, ProverKey};
use zkdl::Fr;

/// T consecutive SGD-step witnesses with real weight updates in between
/// ([`sgd_witness_chain`] plus per-step validation: tests must not start
/// from a broken witness).
fn witness_chain(cfg: ModelConfig, steps: usize, seed: u64) -> Vec<StepWitness> {
    let ds = Dataset::synthetic(64, cfg.width / 2, 4, cfg.r_bits, seed ^ 0x77);
    let wits = sgd_witness_chain(cfg, &ds, steps, seed);
    for wit in &wits {
        wit.validate().expect("witness valid");
    }
    wits
}

#[test]
fn trace_roundtrip_two_steps_depth2() {
    let cfg = ModelConfig::new(2, 8, 4);
    let wits = witness_chain(cfg, 2, 1);
    let tk = TraceKey::setup(cfg, 2);
    let mut rng = Rng::seed_from_u64(10);
    let proof = prove_trace(&tk, &wits, &mut rng);
    verify_trace(&tk, &proof).expect("verifies");
    assert_eq!(proof.steps, 2);
    assert_eq!(proof.coms.len(), 2);
}

#[test]
fn trace_roundtrip_non_power_of_two_steps() {
    // T=3 pads to T̄=4: padding slots must be handled on both sides
    let cfg = ModelConfig::new(2, 8, 4);
    let (tbar, lbar, _) = trace_stack_dims(&cfg, 3);
    assert_eq!((tbar, lbar), (4, 2));
    let wits = witness_chain(cfg, 3, 2);
    let tk = TraceKey::setup(cfg, 3);
    let mut rng = Rng::seed_from_u64(11);
    let proof = prove_trace(&tk, &wits, &mut rng);
    verify_trace(&tk, &proof).expect("verifies");
}

#[test]
fn trace_roundtrip_depth3() {
    // depth ≥ 3 exercises the qz1 stacking term across steps
    let cfg = ModelConfig::new(3, 8, 4);
    let wits = witness_chain(cfg, 2, 3);
    let tk = TraceKey::setup(cfg, 2);
    let mut rng = Rng::seed_from_u64(12);
    let proof = prove_trace(&tk, &wits, &mut rng);
    verify_trace(&tk, &proof).expect("verifies");
}

#[test]
fn trace_roundtrip_depth1_two_steps() {
    // no ReLU layers: no stacking sumcheck, validity still runs per trace
    let cfg = ModelConfig::new(1, 8, 4);
    let wits = witness_chain(cfg, 2, 4);
    let tk = TraceKey::setup(cfg, 2);
    let mut rng = Rng::seed_from_u64(13);
    let proof = prove_trace(&tk, &wits, &mut rng);
    verify_trace(&tk, &proof).expect("verifies");
}

#[test]
fn aggregated_proof_smaller_than_independent_steps() {
    // StepProof size is determined by the configuration (not the witness),
    // so T independent proofs cost exactly T × one proof's bytes.
    let cfg = ModelConfig::new(2, 8, 4);
    let t = 4;
    let wits = witness_chain(cfg, t, 5);
    let pk = ProverKey::setup(cfg);
    let mut rng = Rng::seed_from_u64(14);
    let step_proof = prove_step(&pk, &wits[0], ProofMode::Parallel, &mut rng);
    verify_step(&pk, &step_proof).expect("step verifies");
    let independent_bytes = t * step_proof.size_bytes();

    let tk = TraceKey::setup(cfg, t);
    let trace_proof = prove_trace(&tk, &wits, &mut rng);
    verify_trace(&tk, &trace_proof).expect("trace verifies");
    assert!(
        trace_proof.size_bytes() < independent_bytes,
        "aggregated {} B should beat {} B (T={t} independent steps)",
        trace_proof.size_bytes(),
        independent_bytes
    );
}

#[test]
fn rejects_tampered_step_witness_inside_trace() {
    // mirror integration.rs::proof_rejects_wrong_gradient, but the bad step
    // hides in the middle of an otherwise-honest aggregated trace
    let cfg = ModelConfig::new(2, 8, 4);
    let mut wits = witness_chain(cfg, 3, 6);
    wits[1].layers[1].g_w[3] += 1; // violates (34) in step 1 only
    let tk = TraceKey::setup(cfg, 3);
    let mut rng = Rng::seed_from_u64(15);
    let proof = prove_trace(&tk, &wits, &mut rng);
    assert!(
        verify_trace(&tk, &proof).is_err(),
        "tampered step inside an aggregated trace must not verify"
    );
}

#[test]
fn rejects_forged_sign_bit_inside_trace() {
    let cfg = ModelConfig::new(2, 8, 4);
    let mut wits = witness_chain(cfg, 2, 7);
    let aux = &mut wits[1].layers[0].z_aux;
    let i = aux.sign.iter().position(|&s| s == 1).unwrap_or(0);
    aux.sign[i] = 1 - aux.sign[i];
    let tk = TraceKey::setup(cfg, 2);
    let mut rng = Rng::seed_from_u64(16);
    let proof = prove_trace(&tk, &wits, &mut rng);
    assert!(verify_trace(&tk, &proof).is_err());
}

#[test]
fn rejects_tampered_trace_proof_scalar() {
    let cfg = ModelConfig::new(2, 8, 4);
    let wits = witness_chain(cfg, 2, 8);
    let tk = TraceKey::setup(cfg, 2);
    let mut rng = Rng::seed_from_u64(17);
    let mut proof = prove_trace(&tk, &wits, &mut rng);
    proof.v_z[1] += Fr::ONE;
    assert!(verify_trace(&tk, &proof).is_err());
}

// ---------------------------------------------------------------------------
// zkSGD chained traces
// ---------------------------------------------------------------------------

#[test]
fn chained_trace_roundtrip_with_boundary_padding() {
    // T=3 → 2 boundaries pad to B̄=2; depth 2 exercises the layer axis
    let cfg = ModelConfig::new(2, 8, 4);
    let wits = witness_chain(cfg, 3, 21);
    let tk = TraceKey::setup(cfg, 3);
    let mut rng = Rng::seed_from_u64(31);
    let proof = prove_trace_chained(&tk, &wits, &mut rng).expect("witnesses chain");
    assert!(proof.chain.is_some());
    verify_trace(&tk, &proof).expect("chained trace verifies");
    // the chain argument costs one stacked commitment + 3 IPAs + 1 validity
    // instance; the boundary evaluations cover both live boundaries
    let chain = proof.chain.as_ref().unwrap();
    assert_eq!(chain.v_gw.len(), 2 * cfg.depth);
    assert_eq!(chain.openings.len(), 3);
}

#[test]
fn chained_trace_roundtrip_depth1_and_depth3() {
    for depth in [1usize, 3] {
        let cfg = ModelConfig::new(depth, 8, 4);
        let wits = witness_chain(cfg, 2, 22 + depth as u64);
        let tk = TraceKey::setup(cfg, 2);
        let mut rng = Rng::seed_from_u64(32);
        let proof = prove_trace_chained(&tk, &wits, &mut rng).expect("witnesses chain");
        verify_trace(&tk, &proof).expect("chained trace verifies");
    }
}

#[test]
fn chained_prover_rejects_witnesses_that_do_not_chain() {
    // an out-of-range update remainder (broken boundary) cannot even be
    // witnessed: the chain builder reports the exact boundary and layer
    let cfg = ModelConfig::new(2, 8, 4);
    let mut wits = witness_chain(cfg, 3, 23);
    wits[2].layers[0].w[7] += 1; // step 2's weights are not step 1's update
    let tk = TraceKey::setup(cfg, 3);
    let mut rng = Rng::seed_from_u64(33);
    let err = prove_trace_chained(&tk, &wits, &mut rng);
    assert!(err.is_err(), "broken weight chain must not be provable");
    let msg = format!("{:#}", err.unwrap_err());
    assert!(msg.contains("boundary 1"), "error names the boundary: {msg}");
}

#[test]
fn chained_trace_rejects_tampered_weights_gradients_and_remainders() {
    let cfg = ModelConfig::new(2, 8, 4);
    let wits = witness_chain(cfg, 3, 24);
    let tk = TraceKey::setup(cfg, 3);
    let mut rng = Rng::seed_from_u64(34);
    let proof = prove_trace_chained(&tk, &wits, &mut rng).expect("witnesses chain");
    verify_trace(&tk, &proof).expect("untampered chained trace verifies");

    // W_{t+1} mutated: the chain's boundary openings (and the trace's own
    // weight openings) no longer match
    let mut bad = proof.clone();
    bad.coms[1].com_w[0] = G1::random(&mut rng).to_affine();
    assert!(verify_trace(&tk, &bad).is_err(), "mutated W_{{t+1}} accepted");

    // G_W mutated
    let mut bad = proof.clone();
    bad.coms[0].com_gw[1] = G1::random(&mut rng).to_affine();
    assert!(verify_trace(&tk, &bad).is_err(), "mutated G_W accepted");

    // remainder commitment mutated: block opening + validity fail
    let mut bad = proof.clone();
    bad.chain.as_mut().unwrap().com_u = G1::random(&mut rng).to_affine();
    assert!(verify_trace(&tk, &bad).is_err(), "mutated R accepted");

    // a lying boundary evaluation: the derived remainder claim shifts and
    // the opening IPAs cannot satisfy both sides
    let mut bad = proof.clone();
    bad.chain.as_mut().unwrap().v_w[2] += Fr::ONE;
    assert!(verify_trace(&tk, &bad).is_err(), "lying v_w accepted");

    // stripping the chain flips the transcript's chained flag
    let mut bad = proof.clone();
    bad.chain = None;
    assert!(verify_trace(&tk, &bad).is_err(), "stripped chain accepted");

    // grafting another trace's chain cannot satisfy Fiat–Shamir binding
    let wits_b = witness_chain(cfg, 3, 25);
    let proof_b = prove_trace_chained(&tk, &wits_b, &mut rng).expect("chains");
    let mut bad = proof.clone();
    bad.chain = proof_b.chain.clone();
    assert!(verify_trace(&tk, &bad).is_err(), "grafted chain accepted");
}

/// A T-step heavy-ball momentum chain under a decaying shift schedule,
/// plus the schedule's window table.
fn momentum_chain(
    cfg: ModelConfig,
    steps: usize,
    seed: u64,
) -> (Vec<StepWitness>, UpdateRule, Vec<u32>) {
    let rule = UpdateRule::momentum_default();
    let sched = LrSchedule::StepDecay {
        base: cfg.lr_shift,
        period: 2,
        max: cfg.lr_shift + 2,
    };
    let ds = Dataset::synthetic(64, cfg.width / 2, 4, cfg.r_bits, seed ^ 0x77);
    let wits = rule_witness_chain(cfg, &rule, &sched, &ds, steps, seed);
    for wit in &wits {
        wit.validate().expect("witness valid");
    }
    (wits, rule, sched.window_table(0, steps - 1))
}

#[test]
fn momentum_chained_trace_roundtrip_with_decaying_schedule() {
    // T=4 → 3 boundaries with shifts [8, 8, 9]: per-boundary digit budgets
    // differ inside one instance, and the momentum relation rides at its
    // own fixed budget
    let cfg = ModelConfig::new(2, 8, 4);
    let (wits, rule, table) = momentum_chain(cfg, 4, 41);
    assert!(table.windows(2).any(|w| w[0] != w[1]), "schedule actually decays");
    let tk = TraceKey::setup(cfg, 4);
    let mut rng = Rng::seed_from_u64(51);
    let proof = prove_trace_chained_with(&tk, &wits, &rule, &table, &mut rng)
        .expect("momentum witnesses chain");
    verify_trace(&tk, &proof).expect("momentum chained trace verifies");
    let chain = proof.chain.as_ref().unwrap();
    assert_eq!(chain.v_state.len(), 1);
    assert_eq!(chain.v_state[0].len(), 4 * cfg.depth);
    assert_eq!(chain.openings.len(), 3, "still three opening IPAs");
}

#[test]
fn momentum_prover_rejects_witnesses_that_do_not_chain() {
    let cfg = ModelConfig::new(2, 8, 4);
    let (mut wits, rule, table) = momentum_chain(cfg, 3, 42);
    // perturb the committed accumulator entering step 1
    wits[1].opt_state[0][0][3] += 1;
    let tk = TraceKey::setup(cfg, 3);
    let mut rng = Rng::seed_from_u64(52);
    let err = prove_trace_chained_with(&tk, &wits, &rule, &table, &mut rng);
    assert!(err.is_err(), "broken momentum chain must not be provable");
    let msg = format!("{:#}", err.unwrap_err());
    assert!(msg.contains("momentum"), "error names the relation: {msg}");
}

#[test]
fn momentum_chained_trace_rejects_tampered_state_and_statement() {
    let cfg = ModelConfig::new(2, 8, 4);
    let (wits, rule, table) = momentum_chain(cfg, 3, 43);
    let tk = TraceKey::setup(cfg, 3);
    let mut rng = Rng::seed_from_u64(53);
    let proof = prove_trace_chained_with(&tk, &wits, &rule, &table, &mut rng)
        .expect("momentum witnesses chain");
    verify_trace(&tk, &proof).expect("untampered momentum trace verifies");

    // mutated momentum accumulator commitment m
    let mut bad = proof.clone();
    bad.chain.as_mut().unwrap().com_state[0][1] = G1::random(&mut rng).to_affine();
    assert!(verify_trace(&tk, &bad).is_err(), "mutated m accepted");

    // lying momentum evaluation (the derived remainder claims shift)
    let mut bad = proof.clone();
    bad.chain.as_mut().unwrap().v_state[0][2] += Fr::ONE;
    assert!(verify_trace(&tk, &bad).is_err(), "lying m̃(p) accepted");

    // mutated stacked remainder commitment (covers both relations' tensors)
    let mut bad = proof.clone();
    bad.chain.as_mut().unwrap().com_u = G1::random(&mut rng).to_affine();
    assert!(verify_trace(&tk, &bad).is_err(), "mutated remainders accepted");

    // truncated shift table: statement shape check fails
    let mut bad = proof.clone();
    bad.chain.as_mut().unwrap().lr_shifts.pop();
    assert!(verify_trace(&tk, &bad).is_err(), "truncated shift table accepted");

    // edited shift table entry: transcript + derived claims diverge
    let mut bad = proof.clone();
    bad.chain.as_mut().unwrap().lr_shifts[0] += 1;
    assert!(verify_trace(&tk, &bad).is_err(), "edited shift table accepted");
}

#[test]
fn swapped_rule_tags_fail_both_directions() {
    let cfg = ModelConfig::new(2, 8, 4);
    let mut rng = Rng::seed_from_u64(54);
    let tk = TraceKey::setup(cfg, 3);

    // momentum artifact re-tagged as SGD (state stripped to match shape)
    let (m_wits, rule, table) = momentum_chain(cfg, 3, 44);
    let m_proof = prove_trace_chained_with(&tk, &m_wits, &rule, &table, &mut rng)
        .expect("momentum chains");
    let mut swapped = m_proof.clone();
    {
        let chain = swapped.chain.as_mut().unwrap();
        chain.rule = UpdateRule::Sgd;
        chain.com_state.clear();
        chain.v_state.clear();
    }
    assert!(
        verify_trace(&tk, &swapped).is_err(),
        "momentum artifact verified as sgd"
    );
    // ... and with the state left in place the shape check itself rejects
    let mut swapped = m_proof.clone();
    swapped.chain.as_mut().unwrap().rule = UpdateRule::Sgd;
    assert!(verify_trace(&tk, &swapped).is_err());

    // SGD artifact re-tagged as momentum (zero state grafted on)
    let s_wits = witness_chain(cfg, 3, 45);
    let s_proof = prove_trace_chained(&tk, &s_wits, &mut rng).expect("sgd chains");
    let mut swapped = s_proof.clone();
    {
        let chain = swapped.chain.as_mut().unwrap();
        chain.rule = UpdateRule::momentum_default();
        chain.com_state = vec![vec![zkdl::curve::G1Affine::IDENTITY; 3 * cfg.depth]];
        chain.v_state = vec![vec![Fr::ZERO; 3 * cfg.depth]];
    }
    assert!(
        verify_trace(&tk, &swapped).is_err(),
        "sgd artifact verified as momentum"
    );
}

#[test]
fn sgd_rule_artifacts_match_legacy_entry_point() {
    // the trivial rule is the pre-refactor chain: the compat wrapper and
    // the explicit (Sgd, constant-table) invocation must produce
    // byte-identical artifacts from identical inputs and randomness
    let cfg = ModelConfig::new(2, 8, 4);
    let wits = witness_chain(cfg, 3, 46);
    let tk = TraceKey::setup(cfg, 3);
    let a = prove_trace_chained(&tk, &wits, &mut Rng::seed_from_u64(55)).expect("chains");
    let shifts = vec![cfg.lr_shift; 2];
    let b = prove_trace_chained_with(&tk, &wits, &UpdateRule::Sgd, &shifts, &mut Rng::seed_from_u64(55))
        .expect("chains");
    assert_eq!(
        zkdl::wire::encode_trace_proof(&cfg, &a),
        zkdl::wire::encode_trace_proof(&cfg, &b),
        "SGD rule is byte-for-byte the legacy chain"
    );
    verify_trace(&tk, &a).expect("verifies");
}

#[test]
fn chained_traces_batch_with_one_msm() {
    let cfg = ModelConfig::new(2, 8, 4);
    let tk = TraceKey::setup(cfg, 2);
    let mut rng = Rng::seed_from_u64(35);
    let a = prove_trace_chained(&tk, &witness_chain(cfg, 2, 26), &mut rng).expect("chains");
    let b = prove_trace(&tk, &witness_chain(cfg, 2, 27), &mut rng);
    let mut vrng = Rng::seed_from_u64(36);
    verify_traces_batch(&[(&tk, &a), (&tk, &b)], &mut vrng)
        .expect("mixed chained/unchained batch verifies with one MSM");
}

// ---------------------------------------------------------------------------
// zkFlight failure taxonomy: each tamper is rejected with its phase's class
// ---------------------------------------------------------------------------

/// The typed class a tampered proof is rejected with. Panics if the proof
/// is accepted or the rejection carries no class — every verifier phase
/// must attach one.
fn rejection_class(tk: &TraceKey, proof: &TraceProof) -> VerifyFailureClass {
    let err = verify_trace(tk, proof).expect_err("tampered proof accepted");
    failure_class(&err).unwrap_or_else(|| panic!("rejection carries no failure class: {err:#}"))
}

#[test]
fn tamper_classes_are_distinct_per_phase() {
    // one honest chained trace, seven tampers — each must land in its own
    // class so `zkdl audit` can tell the failure modes apart
    let cfg = ModelConfig::new(2, 8, 4);
    let wits = witness_chain(cfg, 3, 61);
    let tk = TraceKey::setup(cfg, 3);
    let mut rng = Rng::seed_from_u64(71);
    let chained = prove_trace_chained(&tk, &wits, &mut rng).expect("chains");
    verify_trace(&tk, &chained).expect("honest chained trace verifies");

    // shape: a truncated evaluation vector is rejected before any transcript
    let mut bad = chained.clone();
    bad.v_z.pop();
    assert_eq!(rejection_class(&tk, &bad), VerifyFailureClass::Shape);

    // sumcheck: a lying claimed evaluation breaks round consistency
    let mut bad = chained.clone();
    bad.v_z[0] += Fr::ONE;
    assert_eq!(rejection_class(&tk, &bad), VerifyFailureClass::Sumcheck);

    // transcript binding: the sumcheck's final factor evaluations no longer
    // reproduce the claimed product
    let mut bad = chained.clone();
    bad.mm30_evals[0].0 += Fr::ONE;
    assert_eq!(rejection_class(&tk, &bad), VerifyFailureClass::TranscriptBinding);

    // opening: a truncated IPA fold vector fails inside the batched opening
    let mut bad = chained.clone();
    bad.openings[0].l.pop();
    assert_eq!(rejection_class(&tk, &bad), VerifyFailureClass::Opening);

    // validity: the zkReLU range/booleanity instance breaks
    let mut bad = chained.clone();
    bad.validity_main.ipa.l.pop();
    assert_eq!(rejection_class(&tk, &bad), VerifyFailureClass::Validity);

    // chain relation: the zkOptim chain's own opening breaks
    let mut bad = chained.clone();
    bad.chain.as_mut().unwrap().openings[0].l.pop();
    assert_eq!(rejection_class(&tk, &bad), VerifyFailureClass::ChainRelation);

    // msm-final-check: a shifted blind passes every scalar check and is only
    // caught by the deferred one-MSM flush
    let mut bad = chained.clone();
    bad.openings[0].blind += Fr::ONE;
    assert_eq!(rejection_class(&tk, &bad), VerifyFailureClass::MsmFinalCheck);
}

#[test]
fn rejects_spliced_commitments_across_traces() {
    // prove two different traces, then graft trace B's argument onto trace
    // A's commitments — Fiat–Shamir binding must reject the hybrid
    let cfg = ModelConfig::new(2, 8, 4);
    let wits_a = witness_chain(cfg, 2, 9);
    let wits_b = witness_chain(cfg, 2, 10);
    let tk = TraceKey::setup(cfg, 2);
    let mut rng = Rng::seed_from_u64(18);
    let proof_a = prove_trace(&tk, &wits_a, &mut rng);
    let proof_b = prove_trace(&tk, &wits_b, &mut rng);
    let mut hybrid = proof_b.clone();
    hybrid.coms = proof_a.coms.clone();
    assert!(verify_trace(&tk, &hybrid).is_err());
}
