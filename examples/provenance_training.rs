//! zkData end-to-end: commit a dataset once, get its Appendix-B root
//! endorsed, then prove a chained training trace whose every batch is
//! bound to that dataset — the full "trained THIS model on THIS data"
//! statement, verified with one MSM.
//!
//!     cargo run --release --example provenance_training -- --steps 4 --data-n 64
//!
//! Act one builds the dataset commitment and plays the endorser; act two
//! trains and proves with provenance; act three shows the tamper classes
//! being rejected; act four bridges back to the Appendix-B membership
//! audit over the very same root.

use std::time::Instant;
use zkdl::aggregate::{prove_trace_chained_provenance_with, verify_trace, TraceKey};
use zkdl::data::Dataset;
use zkdl::merkle::verify_membership;
use zkdl::model::ModelConfig;
use zkdl::provenance::{verify_dataset_endorsement, ProverDataset, PROVENANCE_HASH};
use zkdl::update::UpdateRule;
use zkdl::util::cli::Cli;
use zkdl::util::rng::Rng;
use zkdl::witness::native::sgd_witness_chain;

fn hex(bytes: &[u8]) -> String {
    bytes.iter().map(|b| format!("{b:02x}")).collect()
}

fn main() -> anyhow::Result<()> {
    let cli = Cli::from_env();
    let steps = cli.get_usize("steps", 4);
    let n = cli.get_usize("data-n", 64);
    let cfg = ModelConfig::new(
        cli.get_usize("depth", 2),
        cli.get_usize("width", 16),
        cli.get_usize("batch", 8),
    );

    // ---- act one: one-time dataset commitment + endorsement ----
    let ds = Dataset::synthetic(n, cfg.width / 2, 4, cfg.r_bits, 21);
    let t = Instant::now();
    let pd = ProverDataset::build(&ds, &cfg)?;
    println!(
        "committed {n} dataset rows in {:.2} s — root {}",
        t.elapsed().as_secs_f64(),
        hex(&pd.commitment.root)
    );
    // the endorser re-derives the root from the released leaves and checks
    // that they sum to the dataset MLE commitment, then signs the root
    verify_dataset_endorsement(&pd.leaves, &pd.commitment.root, &pd.commitment.com_d)?;
    println!("endorser: leaves rebuild the root and sum to com_d — root ENDORSED");

    // ---- act two: chained training trace bound to the dataset ----
    let wits = sgd_witness_chain(cfg, &ds, steps, 0x5eed);
    let tk = TraceKey::setup(cfg, steps);
    let mut rng = Rng::seed_from_u64(1);
    let shifts = vec![cfg.lr_shift; steps - 1];
    let t = Instant::now();
    let proof =
        prove_trace_chained_provenance_with(&tk, &wits, &UpdateRule::Sgd, &shifts, &pd, &mut rng)?;
    let prove_s = t.elapsed().as_secs_f64();
    let t = Instant::now();
    verify_trace(&tk, &proof)?;
    println!(
        "chained+provenance trace over {steps} steps: prove {:.2} s | verify {:.3} s (one MSM) | {:.1} kB",
        prove_s,
        t.elapsed().as_secs_f64(),
        proof.size_bytes() as f64 / 1024.0
    );

    // ---- act three: the tamper classes are rejected ----
    let mut bad = proof.clone();
    bad.provenance.as_mut().unwrap().dataset.root[0] ^= 1;
    assert!(verify_trace(&tk, &bad).is_err());
    println!("swapped endorsement root: REJECTED");
    let mut bad = proof.clone();
    bad.provenance = None;
    assert!(verify_trace(&tk, &bad).is_err());
    println!("stripped provenance payload: REJECTED");
    let mut tampered = wits.clone();
    tampered[0].batch_rows[0] = (tampered[0].batch_rows[0] + 1) % n;
    assert!(prove_trace_chained_provenance_with(
        &tk,
        &tampered,
        &UpdateRule::Sgd,
        &shifts,
        &pd,
        &mut rng
    )
    .is_err());
    println!("swapped batch row: cannot even be witnessed");

    // ---- act four: Appendix-B audit against the SAME root ----
    // a data owner checks their row was (and an outsider's was not) in the
    // endorsed training set — the root the trace proved against
    let row = wits[0].batch_rows[0];
    let member_query = vec![PROVENANCE_HASH.hash(&pd.leaves[row])];
    let mproof = pd.tree.prove(&member_query);
    verify_membership(PROVENANCE_HASH, &pd.commitment.root, &member_query, &mproof)?;
    println!(
        "membership audit: dataset row {row} (used in step 0) IS under the endorsed root ({} hashes)",
        mproof.size_hashes()
    );
    let out_query = vec![PROVENANCE_HASH.hash(b"not a leaf")];
    let oproof = pd.tree.prove(&out_query);
    verify_membership(PROVENANCE_HASH, &pd.commitment.root, &out_query, &oproof)?;
    println!(
        "non-membership audit: outsider NOT under the endorsed root ({} hashes)",
        oproof.size_hashes()
    );
    Ok(())
}
