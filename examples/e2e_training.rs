//! End-to-end driver: train a multi-layer FCNN on synthetic CIFAR-10-shaped
//! data for a few hundred steps, generating and verifying a zkDL proof at a
//! fixed cadence, and log the loss curve + proof metrics.
//!
//!     cargo run --release --example e2e_training -- \
//!         --depth 3 --width 64 --batch 16 --steps 200 --prove-every 20
//!
//! This is the repository's full-system validation run (EXPERIMENTS.md §E2E):
//! it exercises all three layers — the AOT-compiled JAX/Pallas training step
//! through PJRT, the rust witness plumbing, and the full Protocol-2
//! prover/verifier — in one loop.

use std::path::Path;
use zkdl::coordinator::{train_and_prove, TrainOptions};
use zkdl::data::Dataset;
use zkdl::model::ModelConfig;
use zkdl::util::cli::Cli;
use zkdl::zkdl::ProofMode;

fn main() -> anyhow::Result<()> {
    let cli = Cli::from_env();
    let cfg = ModelConfig::new(
        cli.get_usize("depth", 3),
        cli.get_usize("width", 64),
        cli.get_usize("batch", 16),
    );
    let steps = cli.get_usize("steps", 200);
    let prove_every = cli.get_usize("prove-every", 20);
    println!(
        "e2e: L={} d={} B={} ({} params), {} steps, proof every {}",
        cfg.depth,
        cfg.width,
        cfg.batch,
        cfg.param_count(),
        steps,
        prove_every
    );

    let ds = Dataset::synthetic(2048, cfg.width.min(512), 10, cfg.r_bits, 3);
    let opts = TrainOptions {
        steps,
        prove_every,
        mode: ProofMode::Parallel,
        seed: cli.get_u64("seed", 7),
        skip_verify: false,
        pipeline_depth: 2,
    };
    let report = train_and_prove(cfg, &ds, Path::new("artifacts"), &opts)?;

    println!("\nstep   loss      acc    prove(ms)  verify(ms)  proof(kB)");
    for s in report.steps.iter().filter(|s| s.proof_bytes > 0) {
        println!(
            "{:5}  {:8.4}  {:5.2}  {:9.1}  {:10.1}  {:9.1}",
            s.step,
            s.loss,
            s.accuracy,
            s.prove_ms,
            s.verify_ms,
            s.proof_bytes as f64 / 1024.0
        );
    }
    println!("\n{}", report.summary());
    let csv = cli.get_str("csv", "e2e_training.csv").to_string();
    std::fs::write(&csv, report.to_csv())?;
    println!("loss curve + metrics written to {csv}");
    Ok(())
}
