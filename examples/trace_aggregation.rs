//! FAC4DNN multi-step aggregation end-to-end: train T SGD steps through the
//! pipelined coordinator, aggregate them into one `TraceProof`, persist it
//! in the wire format, then re-read and verify it from bytes alone — the
//! out-of-process verifier workflow behind `zkdl verify-trace`.
//!
//!     cargo run --release --example trace_aggregation

use std::path::Path;
use zkdl::aggregate::{verify_trace, TraceKey};
use zkdl::coordinator::{train_and_prove_trace, TraceTrainOptions};
use zkdl::data::Dataset;
use zkdl::model::ModelConfig;
use zkdl::wire::{decode_trace_proof, encode_trace_proof};

fn main() -> anyhow::Result<()> {
    let cfg = ModelConfig::new(2, 16, 8);
    let steps = 8;
    println!(
        "aggregating {steps} proven SGD steps: L={} d={} B={}",
        cfg.depth, cfg.width, cfg.batch
    );

    // 1. pipelined training run feeding the aggregator (one window)
    let ds = Dataset::synthetic(256, 8, 10, cfg.r_bits, 5);
    let opts = TraceTrainOptions {
        steps,
        window: 0, // one trace over the whole run
        seed: 42,
        skip_verify: true, // verified from disk below instead
        ..Default::default()
    };
    let report = train_and_prove_trace(cfg, &ds, Path::new("artifacts"), &opts)?;
    println!("{}", report.summary());
    println!(
        "loss {:.4} → {:.4} over the trace",
        report.losses.first().unwrap(),
        report.losses.last().unwrap()
    );

    // 2. persist the aggregated proof
    let proof = &report.proofs[0];
    let bytes = encode_trace_proof(&cfg, proof);
    println!(
        "trace proof: {:.1} kB ({} wire bytes for {} steps)",
        proof.size_bytes() as f64 / 1024.0,
        bytes.len(),
        proof.steps
    );

    // 3. the verifier's side: reconstruct everything from the bytes
    let (cfg2, decoded) = decode_trace_proof(&bytes)?;
    let tk = TraceKey::setup(cfg2, decoded.steps);
    let t = std::time::Instant::now();
    verify_trace(&tk, &decoded)?;
    println!(
        "re-read from wire and verified in {:.2} s — accept",
        t.elapsed().as_secs_f64()
    );
    Ok(())
}
