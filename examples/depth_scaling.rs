//! Depth scaling (Figure 4 preview): per-step proving time and proof size
//! for parallel (ours) vs sequential (conventional) proof generation as
//! network depth grows.
//!
//!     cargo run --release --example depth_scaling -- --width 16 --batch 8 \
//!         --max-depth 8
//!
//! The full sweep lives in `cargo bench --bench fig4`; this example is the
//! human-sized version.

use std::path::Path;
use std::time::Instant;
use zkdl::data::Dataset;
use zkdl::model::{ModelConfig, Weights};
use zkdl::runtime::WitnessSource;
use zkdl::util::cli::Cli;
use zkdl::util::rng::Rng;
use zkdl::zkdl::{prove_step, verify_step, ProofMode, ProverKey};

fn main() -> anyhow::Result<()> {
    let cli = Cli::from_env();
    let width = cli.get_usize("width", 16);
    let batch = cli.get_usize("batch", 8);
    let max_depth = cli.get_usize("max-depth", 8);

    println!("depth | parallel time  size | sequential time  size");
    println!("------|---------------------|----------------------");
    let mut depth = 2usize;
    while depth <= max_depth {
        let cfg = ModelConfig::new(depth, width, batch);
        let ds = Dataset::synthetic(256, width / 2, 4, cfg.r_bits, 5);
        let (x, y) = ds.batch(&cfg, 0);
        let mut rng = Rng::seed_from_u64(depth as u64);
        let w = Weights::init(cfg, &mut rng);
        let src = WitnessSource::auto(Path::new("artifacts"), cfg);
        let wit = src.compute_witness(&x, &y, &w)?;
        let pk = ProverKey::setup(cfg);

        let mut row = format!("{depth:5} |");
        for mode in [ProofMode::Parallel, ProofMode::Sequential] {
            let t = Instant::now();
            let proof = prove_step(&pk, &wit, mode, &mut rng);
            let secs = t.elapsed().as_secs_f64();
            verify_step(&pk, &proof)?;
            row.push_str(&format!(
                " {:8.2} s {:6.1} kB |",
                secs,
                proof.size_bytes() as f64 / 1024.0
            ));
        }
        println!("{row}");
        depth *= 2;
    }
    println!("\nparallel proof size grows O(log L); sequential grows O(L).");
    Ok(())
}
