//! Quickstart: prove and verify one zkDL training step end-to-end.
//!
//!     cargo run --release --example quickstart
//!
//! Loads the AOT artifact when present (run `make artifacts` first) and
//! falls back to the native witness generator otherwise.

use std::path::Path;
use std::time::Instant;
use zkdl::data::Dataset;
use zkdl::model::{ModelConfig, Weights};
use zkdl::runtime::WitnessSource;
use zkdl::util::rng::Rng;
use zkdl::zkdl::{prove_step, verify_step, ProofMode, ProverKey};

fn main() -> anyhow::Result<()> {
    // a 2-layer, width-64 perceptron on a batch of 16 — Table 2's first row
    let cfg = ModelConfig::new(2, 64, 16);
    println!(
        "zkDL quickstart: L={} d={} B={} ({} parameters)",
        cfg.depth,
        cfg.width,
        cfg.batch,
        cfg.param_count()
    );

    // synthetic CIFAR-10-like data (see DESIGN.md §Documented deviations)
    let ds = Dataset::synthetic(256, 32, 10, cfg.r_bits, 1);
    let (x, y) = ds.batch(&cfg, 0);
    let mut rng = Rng::seed_from_u64(42);
    let weights = Weights::init(cfg, &mut rng);

    // 1. witness: execute the quantized training step (PJRT artifact)
    let src = WitnessSource::auto(Path::new("artifacts"), cfg);
    let t = Instant::now();
    let wit = src.compute_witness(&x, &y, &weights)?;
    println!(
        "witness via {} in {:.1} ms (loss {:.4})",
        src.name(),
        t.elapsed().as_secs_f64() * 1e3,
        wit.loss()
    );
    wit.validate()?;
    println!("witness satisfies relations (2)-(5), (30)-(35)");

    // 2. commit + prove (Protocol 2, parallel order)
    let t = Instant::now();
    let pk = ProverKey::setup(cfg);
    println!("one-time key setup: {:.2} s", t.elapsed().as_secs_f64());
    let t = Instant::now();
    let proof = prove_step(&pk, &wit, ProofMode::Parallel, &mut rng);
    println!(
        "proof generated in {:.2} s — {:.1} kB",
        t.elapsed().as_secs_f64(),
        proof.size_bytes() as f64 / 1024.0
    );

    // 3. verify
    let t = Instant::now();
    verify_step(&pk, &proof)?;
    println!("verified in {:.2} s — accept", t.elapsed().as_secs_f64());
    Ok(())
}
