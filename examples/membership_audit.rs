//! Data-copyright audit (paper §4.4 / §5.2): a copyright owner queries
//! whether their data points were part of the committed training set.
//!
//!     cargo run --release --example membership_audit -- --n 5000 --hash md5
//!
//! Demonstrates both outcomes: members get membership proofs, outsiders get
//! non-membership proofs, and a lying trainer is caught. Also reports the
//! naive alternative (scanning every commitment) for the paper's
//! 0.05 ms-vs-14 s comparison.

use std::time::Instant;
use zkdl::commit::CommitKey;
use zkdl::data::Dataset;
use zkdl::hash::HashFn;
use zkdl::merkle::{point_leaf, verify_membership, MerkleTree};
use zkdl::util::cli::Cli;
use zkdl::Fr;

fn main() -> anyhow::Result<()> {
    let cli = Cli::from_env();
    let n = cli.get_usize("n", 5000);
    let dim = cli.get_usize("dim", 64);
    let hash = HashFn::parse(cli.get_str("hash", "sha256")).expect("md5|sha1|sha256");

    // 1. trainer commits every data point deterministically (§3.1); leaves
    // use the canonical 32-byte compressed-point codec shared with the
    // wire format, so endorsement material and artifacts agree byte-wise
    let ds = Dataset::synthetic(n, dim, 10, 16, 11);
    let ck = CommitKey::setup(b"zkdl/data", dim);
    let t = Instant::now();
    let coms: Vec<Vec<u8>> = ds
        .points
        .iter()
        .map(|p| {
            let frs: Vec<Fr> = p.iter().map(|&v| Fr::from_i64(v)).collect();
            point_leaf(&ck.commit_deterministic(&frs).to_affine())
        })
        .collect();
    println!("committed {n} data points in {:.2} s", t.elapsed().as_secs_f64());

    // 2. build the frontier-augmented Merkle tree; root gets endorsed
    let t = Instant::now();
    let tree = MerkleTree::build(hash, &coms);
    println!(
        "merkle tree ({}, k={} bits) built in {:.2} s — root endorsed",
        hash.name(),
        tree.k,
        t.elapsed().as_secs_f64()
    );

    // 3a. a member audits their data point
    let member_query = vec![hash.hash(&coms[17])];
    let proof = tree.prove(&member_query);
    let t = Instant::now();
    verify_membership(hash, &tree.root, &member_query, &proof)?;
    println!(
        "member audit: IN training set — {} hashes, verified in {:.3} ms",
        proof.size_hashes(),
        t.elapsed().as_secs_f64() * 1e3
    );

    // 3b. an outsider confirms their work was NOT trained on
    let outsider = Dataset::synthetic(1, dim, 10, 16, 999);
    let frs: Vec<Fr> = outsider.points[0].iter().map(|&v| Fr::from_i64(v)).collect();
    let out_com = point_leaf(&ck.commit_deterministic(&frs).to_affine());
    let out_query = vec![hash.hash(&out_com)];
    let proof = tree.prove(&out_query);
    let t = Instant::now();
    verify_membership(hash, &tree.root, &out_query, &proof)?;
    let fast_ms = t.elapsed().as_secs_f64() * 1e3;
    println!(
        "outsider audit: NOT in training set — {} hashes, verified in {:.3} ms",
        proof.size_hashes(),
        fast_ms
    );

    // naive alternative: scan every commitment
    let t = Instant::now();
    let found = coms.iter().any(|c| *c == out_com);
    let scan_ms = t.elapsed().as_secs_f64() * 1e3;
    println!(
        "naive full scan: found={found} in {:.1} ms ({}x slower, and reveals the dataset)",
        scan_ms,
        (scan_ms / fast_ms.max(1e-6)).round()
    );

    // 4. a lying trainer is caught
    let mut lying = tree.prove(&member_query);
    lying.included.clear();
    lying.excluded.push(member_query[0].clone());
    assert!(verify_membership(hash, &tree.root, &member_query, &lying).is_err());
    println!("lying trainer (member claimed excluded): proof REJECTED");
    Ok(())
}
