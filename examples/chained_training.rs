//! zkSGD chained training end-to-end: train 4 SGD steps through the
//! pipelined coordinator, aggregate them into one *chained* `TraceProof` —
//! every boundary's weights proven to be the exact quantized update
//! W_{t+1} = W_t − ⌊G_W/2^{R+lr}⌉ of the previous step — persist it in the
//! wire format, then re-read and verify it from bytes alone.
//!
//!     cargo run --release --example chained_training

use std::path::Path;
use zkdl::aggregate::{prove_trace, verify_trace, TraceKey};
use zkdl::coordinator::{train_and_prove_trace, TraceTrainOptions};
use zkdl::data::Dataset;
use zkdl::model::ModelConfig;
use zkdl::wire::{decode_trace_proof, encode_trace_proof};

fn main() -> anyhow::Result<()> {
    let cfg = ModelConfig::new(2, 16, 8);
    let steps = 4;
    println!(
        "chained trace of {steps} proven SGD steps: L={} d={} B={} (lr = 2^-{})",
        cfg.depth, cfg.width, cfg.batch, cfg.lr_shift
    );

    // 1. pipelined training run; the aggregator proves the window with the
    //    zkSGD chain argument appended
    let ds = Dataset::synthetic(256, 8, 10, cfg.r_bits, 5);
    let opts = TraceTrainOptions {
        steps,
        window: 0, // one chained trace over the whole run
        seed: 42,
        skip_verify: true, // verified from disk below instead
        chained: true,
        ..Default::default()
    };
    let report = train_and_prove_trace(cfg, &ds, Path::new("artifacts"), &opts)?;
    println!("{}", report.summary());
    println!(
        "loss {:.4} → {:.4} over the chained trace",
        report.losses.first().unwrap(),
        report.losses.last().unwrap()
    );

    // 2. persist the chained proof and compare against the unchained cost
    let proof = &report.proofs[0];
    let chain = proof
        .chain
        .as_ref()
        .expect("coordinator produced a chained window");
    let bytes = encode_trace_proof(&cfg, proof);
    println!(
        "chained trace proof: {:.1} kB total, {:.1} kB of it the chain ({} boundaries, {} wire bytes)",
        proof.size_bytes() as f64 / 1024.0,
        chain.size_bytes() as f64 / 1024.0,
        chain.v_gw.len() / cfg.depth,
        bytes.len(),
    );

    // 3. the verifier's side: reconstruct everything from the bytes; the
    //    chain rides the trace's single deferred MSM
    let (cfg2, decoded) = decode_trace_proof(&bytes)?;
    let tk = TraceKey::setup(cfg2, decoded.steps);
    let t = std::time::Instant::now();
    verify_trace(&tk, &decoded)?;
    println!(
        "re-read from wire and verified in {:.2} s (one MSM, chain included) — accept",
        t.elapsed().as_secs_f64()
    );

    // 4. the property the chain buys: an unchained proof over *tampered*
    //    step-2 weights still verifies (each step is self-consistent), but
    //    the chained prover refuses the same tamper outright
    let mut rng = zkdl::util::rng::Rng::seed_from_u64(7);
    let mut wits = zkdl::witness::native::sgd_witness_chain(cfg, &ds, steps, 7);
    wits[2].layers[0].w[0] += 1i64 << cfg.r_bits; // a whole unit of drift
    // the drifted weights break relation (30) inside step 2, so rebuild a
    // self-consistent witness from them — this is the "trainer substituted
    // different weights mid-run" attack
    {
        use zkdl::model::Weights;
        use zkdl::witness::native::compute_witness;
        let drifted = Weights {
            layers: wits[2].layers.iter().map(|l| l.w.clone()).collect(),
            cfg,
        };
        let (x, y) = ds.batch(&cfg, 2);
        wits[2] = compute_witness(cfg, &x, &y, &drifted);
    }
    let tk4 = TraceKey::setup(cfg, steps);
    let unchained = prove_trace(&tk4, &wits, &mut rng);
    verify_trace(&tk4, &unchained)?;
    println!("unchained proof of the drifted run: ACCEPTED (steps are only individually checked)");
    match zkdl::aggregate::prove_trace_chained(&tk4, &wits, &mut rng) {
        Err(e) => println!("chained prover on the drifted run: REFUSED ({e:#})"),
        Ok(_) => anyhow::bail!("drifted run must not be chainable"),
    }
    Ok(())
}
