//! zkOptim chained training end-to-end: train SGD steps through the
//! pipelined coordinator, aggregate them into one *chained* `TraceProof` —
//! every boundary's weights proven to be the exact quantized update of the
//! previous step — persist it in the wire format, then re-read and verify
//! it from bytes alone. A second act proves a *momentum* run under a
//! decaying learning-rate schedule: the same chain argument, driven by a
//! different rule table (two relations, a committed accumulator m, and a
//! per-boundary shift table).
//!
//!     cargo run --release --example chained_training

use std::path::Path;
use zkdl::aggregate::{prove_trace, prove_trace_chained_with, verify_trace, TraceKey};
use zkdl::coordinator::{train_and_prove_trace, TraceTrainOptions};
use zkdl::data::Dataset;
use zkdl::model::ModelConfig;
use zkdl::update::{LrSchedule, UpdateRule};
use zkdl::wire::{decode_trace_proof, encode_trace_proof};

fn main() -> anyhow::Result<()> {
    let cfg = ModelConfig::new(2, 16, 8);
    let steps = 4;
    println!(
        "chained trace of {steps} proven SGD steps: L={} d={} B={} (lr = 2^-{})",
        cfg.depth, cfg.width, cfg.batch, cfg.lr_shift
    );

    // 1. pipelined training run; the aggregator proves the window with the
    //    zkOptim chain argument appended (plain-SGD rule)
    let ds = Dataset::synthetic(256, 8, 10, cfg.r_bits, 5);
    let opts = TraceTrainOptions {
        steps,
        window: 0, // one chained trace over the whole run
        seed: 42,
        skip_verify: true, // verified from disk below instead
        chained: true,
        ..Default::default()
    };
    let report = train_and_prove_trace(cfg, &ds, Path::new("artifacts"), &opts)?;
    println!("{}", report.summary());
    println!(
        "loss {:.4} → {:.4} over the chained trace",
        report.losses.first().unwrap(),
        report.losses.last().unwrap()
    );

    // 2. persist the chained proof and compare against the unchained cost
    let proof = &report.proofs[0];
    let chain = proof
        .chain
        .as_ref()
        .expect("coordinator produced a chained window");
    let bytes = encode_trace_proof(&cfg, proof);
    println!(
        "chained trace proof: {:.1} kB total, {:.1} kB of it the chain ({} boundaries, {} wire bytes)",
        proof.size_bytes() as f64 / 1024.0,
        chain.size_bytes() as f64 / 1024.0,
        chain.v_gw.len() / cfg.depth,
        bytes.len(),
    );

    // 3. the verifier's side: reconstruct everything from the bytes; the
    //    chain rides the trace's single deferred MSM
    let (cfg2, decoded) = decode_trace_proof(&bytes)?;
    let tk = TraceKey::setup(cfg2, decoded.steps);
    let t = std::time::Instant::now();
    verify_trace(&tk, &decoded)?;
    println!(
        "re-read from wire and verified in {:.2} s (one MSM, chain included) — accept",
        t.elapsed().as_secs_f64()
    );

    // 4. the property the chain buys: an unchained proof over *tampered*
    //    step-2 weights still verifies (each step is self-consistent), but
    //    the chained prover refuses the same tamper outright
    let mut rng = zkdl::util::rng::Rng::seed_from_u64(7);
    let mut wits = zkdl::witness::native::sgd_witness_chain(cfg, &ds, steps, 7);
    wits[2].layers[0].w[0] += 1i64 << cfg.r_bits; // a whole unit of drift
    // the drifted weights break relation (30) inside step 2, so rebuild a
    // self-consistent witness from them — this is the "trainer substituted
    // different weights mid-run" attack
    {
        use zkdl::model::Weights;
        use zkdl::witness::native::compute_witness;
        let drifted = Weights {
            layers: wits[2].layers.iter().map(|l| l.w.clone()).collect(),
            cfg,
        };
        let (x, y) = ds.batch(&cfg, 2);
        wits[2] = compute_witness(cfg, &x, &y, &drifted);
    }
    let tk4 = TraceKey::setup(cfg, steps);
    let unchained = prove_trace(&tk4, &wits, &mut rng);
    verify_trace(&tk4, &unchained)?;
    println!("unchained proof of the drifted run: ACCEPTED (steps are only individually checked)");
    match zkdl::aggregate::prove_trace_chained(&tk4, &wits, &mut rng) {
        Err(e) => println!("chained prover on the drifted run: REFUSED ({e:#})"),
        Ok(_) => anyhow::bail!("drifted run must not be chainable"),
    }

    // 5. zkOptim act two — heavy-ball momentum under a *decaying* lr
    //    schedule: lr starts at 2^-8 and halves every 2 steps. The chain
    //    now proves two relations per boundary (accumulator decay + weight
    //    step), each remainder range-checked at its own digit budget, and
    //    the per-boundary shift table rides the artifact as statement.
    let rule = UpdateRule::momentum_default();
    let schedule = LrSchedule::StepDecay {
        base: cfg.lr_shift,
        period: 2,
        max: cfg.lr_shift + 4,
    };
    println!(
        "\nmomentum act: optimizer={} (β = 7/8), lr 2^-{} decaying every 2 steps",
        rule.name(),
        cfg.lr_shift
    );
    let m_wits =
        zkdl::witness::native::rule_witness_chain(cfg, &rule, &schedule, &ds, steps, 43);
    let table = schedule.window_table(0, steps - 1);
    println!("per-boundary shift table: {table:?}");
    let m_proof = prove_trace_chained_with(&tk4, &m_wits, &rule, &table, &mut rng)?;
    let m_bytes = encode_trace_proof(&cfg, &m_proof);
    let (m_cfg, m_decoded) = decode_trace_proof(&m_bytes)?;
    let m_chain = m_decoded.chain.as_ref().expect("momentum chain present");
    println!(
        "momentum chained proof: {:.1} kB ({} state commitments, rule tag {:?}, shifts {:?})",
        m_decoded.size_bytes() as f64 / 1024.0,
        m_chain.com_state.iter().map(|r| r.len()).sum::<usize>(),
        m_chain.rule.name(),
        m_chain.lr_shifts,
    );
    verify_trace(&TraceKey::setup(m_cfg, m_decoded.steps), &m_decoded)?;
    println!("momentum trace re-read from wire and verified (one MSM) — accept");

    // ... and the momentum trace is NOT an SGD trace: re-tagging the rule
    // breaks the transcript binding
    let mut swapped = m_proof.clone();
    if let Some(c) = swapped.chain.as_mut() {
        c.rule = UpdateRule::Sgd;
        c.com_state.clear();
        c.v_state.clear();
    }
    match verify_trace(&tk4, &swapped) {
        Err(e) => println!("momentum artifact re-tagged as sgd: REJECTED ({e:#})"),
        Ok(_) => anyhow::bail!("rule-tag swap must not verify"),
    }
    Ok(())
}
